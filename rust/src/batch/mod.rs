//! Batched execution engine (paper §4 "Design considerations for GPUs").
//!
//! The inherently parallel ULV factorization issues its per-level work as
//! *batched* kernel launches — the paper's cuBLAS/cuSOLVER batched calls.
//! The backend contract is the arena-native [`device::Device`] trait: a
//! backend executes [`device::Launch`]es (opcode + `BufferId` operand
//! lists, the plan IR's own vocabulary) against a device-owned
//! [`device::DeviceArena`], so residency, streams, and fences belong to
//! the backend. In-tree implementations:
//!
//! * [`native::NativeBackend`] — thread-pool execution of each batch item
//!   with the from-scratch [`crate::linalg`] kernels (the paper's CPU path);
//! * [`crate::solver::backend::SerialBackend`] — single-threaded golden
//!   reference, bit-identical to native;
//! * [`crate::runtime::PjrtBackend`] — constant-shape, zero-padded batches
//!   executed by AOT-compiled XLA executables (the paper's GPU path; see
//!   `python/compile/` for the JAX/Pallas kernels).
//!
//! Two composable wrappers turn any of the above into richer executors:
//! [`device::AsyncDevice`] overlaps adjacent tree levels on multiple
//! stream queues with a `BufferId`-granular hazard tracker (the spec name
//! is `async:<inner>`), and [`device::ValidatingDevice`] audits every
//! launch against arena state (liveness, out-of-range ids, intra-launch
//! write aliasing) before executing it.
//!
//! Padding follows the paper: batch elements are padded to the level
//! maximum (multiples of 4), and POTRF padding writes unit diagonals so the
//! Cholesky never divides by zero (the paper's "batched AXPY ... via a
//! degenerate GEMM" trick).
//!
//! The pre-redesign slice-based [`BatchExec`] trait is deprecated; use
//! [`device::LegacyBatchExec`] to adapt a [`device::Device`] for old call
//! sites until they migrate.

pub mod device;
pub mod native;
pub mod pad;

pub use device::{
    AsyncDevice, Device, DeviceArena, HostArena, Launch, LegacyBatchExec, ValidatingDevice,
    VecRegion, Workspace, WorkspacePool,
};

use crate::linalg::Matrix;

/// Backend-neutral batched kernels over host slices — the pre-redesign
/// backend contract, superseded by the arena-native [`device::Device`]
/// trait (which backends now implement directly and the plan executor
/// drives without per-launch slice reconstruction).
///
/// Kept only so slice-based research code and micro-benches compile via
/// [`device::LegacyBatchExec`]; every call through this trait round-trips
/// host memory per launch.
#[deprecated(
    since = "0.1.0",
    note = "implement batch::device::Device; wrap a Device in \
            batch::device::LegacyBatchExec for slice-based call sites"
)]
pub trait BatchExec: Sync {
    /// In-place lower Cholesky of each block.
    fn potrf(&self, level: usize, blocks: &mut [Matrix]);

    /// `B_t <- B_t * L_tᵀ⁻¹` for each t (right-side lower-transposed TRSM —
    /// the ULV panel solve `L_ji = A_ji L_iiᵀ⁻¹`).
    fn trsm_right_lt(&self, level: usize, l: &[&Matrix], b: &mut [Matrix]);

    /// `C_t <- C_t - A_t A_tᵀ` (SYRK-shaped Schur update of `A^SS`).
    fn schur_self(&self, level: usize, a: &[&Matrix], c: &mut [Matrix]);

    /// Two-sided basis transform `F_t = U_tᵀ A_t V_t` (matrix
    /// sparsification, paper Figure 2). `U`/`V` are square orthogonal.
    fn sparsify(&self, level: usize, u: &[&Matrix], a: &[Matrix], v: &[&Matrix]) -> Vec<Matrix>;

    /// Batched `y_t <- L_t⁻¹ x_t` (forward TRSV on the diagonal blocks).
    fn trsv_fwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]);

    /// Batched `y_t <- L_tᵀ⁻¹ x_t` (backward TRSV).
    fn trsv_bwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]);

    /// Batched GEMV accumulate `y_t += alpha * op(A_t) x_t`. `trans` selects
    /// `A` (false) or `Aᵀ` (true). Off-diagonal substitution updates.
    fn gemv_acc(
        &self,
        level: usize,
        alpha: f64,
        a: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
        y: &mut [Vec<f64>],
    );

    /// Batched small dense `y_t = U_tᵀ x_t` / `y_t = U_t x_t` (basis applied
    /// to vectors during substitution). `trans=true` applies `Uᵀ`.
    fn apply_basis(&self, level: usize, u: &[&Matrix], trans: bool, x: &[&[f64]]) -> Vec<Vec<f64>>;

    /// Human-readable backend name (diagnostics / traces).
    fn name(&self) -> &'static str;
}

/// FLOP-count helpers shared by backends.
pub(crate) fn count_sparsify_flops(u: &Matrix, a: &Matrix, v: &Matrix) {
    use crate::metrics::flops;
    flops::add(flops::gemm_flops(u.cols(), a.cols(), u.rows()));
    flops::add(flops::gemm_flops(u.cols(), v.cols(), a.cols()));
    let _ = v;
}
