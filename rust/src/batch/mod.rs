//! Batched execution engine (paper §4 "Design considerations for GPUs").
//!
//! The inherently parallel ULV factorization issues its per-level work as
//! *batched* kernel launches — the paper's cuBLAS/cuSOLVER batched calls.
//! This module defines the backend-neutral interface ([`BatchExec`]) plus:
//!
//! * [`native::NativeBackend`] — thread-pool execution of each batch item
//!   with the from-scratch [`crate::linalg`] kernels (the paper's CPU path);
//! * [`crate::runtime::PjrtBackend`] — constant-shape, zero-padded batches
//!   executed by AOT-compiled XLA executables (the paper's GPU path; see
//!   `python/compile/` for the JAX/Pallas kernels).
//!
//! Padding follows the paper: batch elements are padded to the level
//! maximum (multiples of 4), and POTRF padding writes unit diagonals so the
//! Cholesky never divides by zero (the paper's "batched AXPY ... via a
//! degenerate GEMM" trick).

pub mod native;
pub mod pad;

use crate::linalg::Matrix;

/// Which backend executes batched kernels.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum BackendChoice {
    /// Thread-pool native kernels (CPU path).
    #[default]
    Native,
    /// AOT XLA executables through PJRT (GPU-analog path). Falls back to
    /// native per-op when an artifact for the shape bucket is missing.
    Pjrt,
}

/// Backend-neutral batched kernels used by the ULV factorization and the
/// parallel substitution. Every method is a single conceptual "launch";
/// implementations may further split batches by shape bucket.
///
/// Shapes within one call are homogeneous unless noted; the coordinator
/// (see [`crate::ulv`]) groups work accordingly, zero-padding per level the
/// way the paper pads to the level's maximum rank.
pub trait BatchExec: Sync {
    /// In-place lower Cholesky of each block.
    fn potrf(&self, level: usize, blocks: &mut [Matrix]);

    /// `B_t <- B_t * L_tᵀ⁻¹` for each t (right-side lower-transposed TRSM —
    /// the ULV panel solve `L_ji = A_ji L_iiᵀ⁻¹`).
    fn trsm_right_lt(&self, level: usize, l: &[&Matrix], b: &mut [Matrix]);

    /// `C_t <- C_t - A_t A_tᵀ` (SYRK-shaped Schur update of `A^SS`).
    fn schur_self(&self, level: usize, a: &[&Matrix], c: &mut [Matrix]);

    /// Two-sided basis transform `F_t = U_tᵀ A_t V_t` (matrix
    /// sparsification, paper Figure 2). `U`/`V` are square orthogonal.
    fn sparsify(&self, level: usize, u: &[&Matrix], a: &[Matrix], v: &[&Matrix]) -> Vec<Matrix>;

    /// Batched `y_t <- L_t⁻¹ x_t` (forward TRSV on the diagonal blocks).
    fn trsv_fwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]);

    /// Batched `y_t <- L_tᵀ⁻¹ x_t` (backward TRSV).
    fn trsv_bwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]);

    /// Batched GEMV accumulate `y_t += alpha * op(A_t) x_t`. `trans` selects
    /// `A` (false) or `Aᵀ` (true). Off-diagonal substitution updates.
    fn gemv_acc(
        &self,
        level: usize,
        alpha: f64,
        a: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
        y: &mut [Vec<f64>],
    );

    /// Batched small dense `y_t = U_tᵀ x_t` / `y_t = U_t x_t` (basis applied
    /// to vectors during substitution). `trans=true` applies `Uᵀ`.
    fn apply_basis(&self, level: usize, u: &[&Matrix], trans: bool, x: &[&[f64]]) -> Vec<Vec<f64>>;

    /// Human-readable backend name (diagnostics / traces).
    fn name(&self) -> &'static str;
}

/// FLOP-count helpers shared by backends.
pub(crate) fn count_sparsify_flops(u: &Matrix, a: &Matrix, v: &Matrix) {
    use crate::metrics::flops;
    flops::add(flops::gemm_flops(u.cols(), a.cols(), u.rows()));
    flops::add(flops::gemm_flops(u.cols(), v.cols(), a.cols()));
    let _ = v;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_choice_default() {
        assert_eq!(BackendChoice::default(), BackendChoice::Native);
    }
}
