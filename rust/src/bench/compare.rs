//! Trajectory comparator: diff two [`BenchReport`]s scenario by scenario.
//!
//! Counters derived from the plan IR and the arena — launch counts,
//! useful/padded FLOPs, peak bytes — are bit-deterministic for a fixed
//! structure, so *any* increase is a regression and any decrease an
//! improvement; both are reported, only increases gate. Wall times are
//! noisy, so they only gate when the caller passes a relative
//! `time_threshold > 0` (e.g. `0.5` = fail if 50 % slower); the CI smoke
//! job runs with 0 (report-only), keeping the gate machine-independent.

use super::{BenchReport, ScenarioReport};
use crate::metrics::run_trace::RunReport;

/// How a metric participates in the regression gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricClass {
    /// Deterministic counter: `after > before` regresses unconditionally.
    Counter,
    /// Measured wall time: regresses only past the relative threshold.
    Time,
}

/// One metric's before/after on one scenario.
#[derive(Clone, Debug)]
pub struct Delta {
    pub scenario: String,
    pub metric: &'static str,
    pub class: MetricClass,
    pub before: f64,
    pub after: f64,
    /// Whether this delta trips the gate (per the class rules above).
    pub regressed: bool,
}

impl Delta {
    /// Relative change `(after - before) / before` (0 when before is 0).
    pub fn relative(&self) -> f64 {
        if self.before == 0.0 {
            return 0.0;
        }
        (self.after - self.before) / self.before
    }
}

/// The full diff of two trajectory files.
#[derive(Clone, Debug, Default)]
pub struct Comparison {
    /// Every changed metric on every matched scenario.
    pub deltas: Vec<Delta>,
    /// Scenario names present only in the newer report.
    pub added: Vec<String>,
    /// Scenario names present only in the older report.
    pub dropped: Vec<String>,
}

/// The gated counters, in report order.
fn counters(r: &RunReport) -> [(&'static str, f64); 6] {
    [
        ("factor_launches", r.factor_launches as f64),
        ("factor_flops", r.factor_flops as f64),
        ("factor_padded_flops", r.factor_padded_flops as f64),
        ("arena_bytes", r.arena_bytes as f64),
        ("arena_peak_bytes", r.arena_peak_bytes as f64),
        ("predicted_peak_bytes", r.predicted_peak_bytes as f64),
    ]
}

fn times(r: &RunReport) -> [(&'static str, f64); 2] {
    [("factor_time", r.factor_time), ("solve_time", r.solve_time)]
}

fn diff_scenario(
    prev: &ScenarioReport,
    cur: &ScenarioReport,
    time_threshold: f64,
    out: &mut Vec<Delta>,
) {
    for ((name, before), (_, after)) in counters(&prev.run).into_iter().zip(counters(&cur.run)) {
        if before != after {
            out.push(Delta {
                scenario: cur.name.clone(),
                metric: name,
                class: MetricClass::Counter,
                before,
                after,
                regressed: after > before,
            });
        }
    }
    for ((name, before), (_, after)) in times(&prev.run).into_iter().zip(times(&cur.run)) {
        if before != after {
            let regressed = time_threshold > 0.0 && after > before * (1.0 + time_threshold);
            out.push(Delta {
                scenario: cur.name.clone(),
                metric: name,
                class: MetricClass::Time,
                before,
                after,
                regressed,
            });
        }
    }
}

/// Diff `cur` against `prev`, joining scenarios by name. Unmatched
/// scenarios are listed as added/dropped and never gate — growing the
/// matrix must not fail the trajectory check.
pub fn compare(prev: &BenchReport, cur: &BenchReport, time_threshold: f64) -> Comparison {
    let mut cmp = Comparison::default();
    for c in &cur.scenarios {
        match prev.scenarios.iter().find(|p| p.name == c.name) {
            Some(p) => diff_scenario(p, c, time_threshold, &mut cmp.deltas),
            None => cmp.added.push(c.name.clone()),
        }
    }
    for p in &prev.scenarios {
        if !cur.scenarios.iter().any(|c| c.name == p.name) {
            cmp.dropped.push(p.name.clone());
        }
    }
    cmp
}

impl Comparison {
    /// Whether any delta trips the gate (the CLI's non-zero exit).
    pub fn has_regressions(&self) -> bool {
        self.deltas.iter().any(|d| d.regressed)
    }

    /// Deltas that trip the gate.
    pub fn regressions(&self) -> Vec<&Delta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    /// Human-readable diff (the `bench --compare` report body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        if self.deltas.is_empty() && self.added.is_empty() && self.dropped.is_empty() {
            out.push_str("no differences\n");
            return out;
        }
        for d in &self.deltas {
            let mark = if d.regressed { "REGRESSION" } else { "changed" };
            out.push_str(&format!(
                "{mark:<10} {} :: {} {} -> {} ({:+.1}%)\n",
                d.scenario,
                d.metric,
                d.before,
                d.after,
                1e2 * d.relative()
            ));
        }
        for name in &self.added {
            out.push_str(&format!("added      {name}\n"));
        }
        for name in &self.dropped {
            out.push_str(&format!("dropped    {name}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::{sample_bench, sample_run};
    use super::*;

    #[test]
    fn identical_reports_have_no_deltas() {
        let r = sample_bench();
        let cmp = compare(&r, &r, 0.0);
        assert!(cmp.deltas.is_empty());
        assert!(!cmp.has_regressions());
        assert_eq!(cmp.render(), "no differences\n");
    }

    #[test]
    fn counter_increase_regresses_decrease_reports_only() {
        let prev = sample_bench();
        let mut cur = prev.clone();
        cur.scenarios[0].run.factor_flops += 100; // worse: more work
        cur.scenarios[1].run.factor_launches -= 1; // better: fewer launches
        let cmp = compare(&prev, &cur, 0.0);
        assert_eq!(cmp.deltas.len(), 2);
        let worse = cmp.deltas.iter().find(|d| d.metric == "factor_flops").unwrap();
        assert!(worse.regressed);
        assert_eq!(worse.scenario, "native/sphere-laplace/rhs1");
        assert!((worse.after - worse.before - 100.0).abs() < 1e-9);
        let better = cmp.deltas.iter().find(|d| d.metric == "factor_launches").unwrap();
        assert!(!better.regressed);
        assert!(cmp.has_regressions());
        assert_eq!(cmp.regressions().len(), 1);
        assert!(cmp.render().contains("REGRESSION"));
    }

    #[test]
    fn time_gates_only_past_threshold() {
        let prev = sample_bench();
        let mut cur = prev.clone();
        cur.scenarios[0].run.factor_time = 0.6; // +20 % over 0.5
        // Report-only mode: time changes never gate.
        assert!(!compare(&prev, &cur, 0.0).has_regressions());
        // 50 % threshold: +20 % passes.
        assert!(!compare(&prev, &cur, 0.5).has_regressions());
        // 10 % threshold: +20 % fails.
        let cmp = compare(&prev, &cur, 0.1);
        assert!(cmp.has_regressions());
        assert_eq!(cmp.regressions()[0].metric, "factor_time");
        assert_eq!(cmp.regressions()[0].class, MetricClass::Time);
        let rel = cmp.deltas[0].relative();
        assert!((rel - 0.2).abs() < 1e-9, "{rel}");
    }

    #[test]
    fn added_and_dropped_scenarios_never_gate() {
        let prev = sample_bench();
        let mut cur = prev.clone();
        cur.scenarios.remove(1);
        cur.scenarios.push(ScenarioReport {
            name: "native/fuzz-9".to_string(),
            kernel: "gaussian".to_string(),
            distribution: "clustered".to_string(),
            run: sample_run(5_000, 0.1),
        });
        let cmp = compare(&prev, &cur, 0.0);
        assert_eq!(cmp.added, vec!["native/fuzz-9".to_string()]);
        assert_eq!(cmp.dropped, vec!["serial/sphere-laplace/rhs1".to_string()]);
        assert!(!cmp.has_regressions());
        let text = cmp.render();
        assert!(text.contains("added      native/fuzz-9"), "{text}");
        assert!(text.contains("dropped    serial/sphere-laplace/rhs1"), "{text}");
    }
}
