//! The canonical seeded problem generator (structure fuzz).
//!
//! One [`Case`] describes everything needed to build an H² test/bench
//! problem: tree shape (n, leaf, rank, eta), far-field sampling, RHS
//! count, kernel, and point distribution. It started life in
//! `tests/common` (PR 5); it now lives in the library so the benchmark
//! sweep, the CLI `plan-lint` fuzzer, and the integration tests all draw
//! from one generator — `tests/common` re-exports it.
//!
//! `Display` is meant for assertion messages: a failing seed reproduces
//! from test output alone.
//!
//! ## SPD envelope
//!
//! Every drawn combination must factorize (ULV = Cholesky at heart).
//! The uniform sphere with the singular `1/r`-type kernels (laplace,
//! yukawa) is the envelope the fixed fixtures proved out: Fibonacci
//! spacing bounds `1/r` off-diagonals well below the `diag = 1e3`
//! regularization. Clustered distributions concentrate points, so they
//! pair only with the *bounded* kernels (gaussian, matérn-3/2, both
//! ≤ 1 off-diagonal): with n ≤ 768 < diag, those matrices are strictly
//! diagonally dominant — SPD regardless of how uneven the blobs are.

use crate::construct::H2Config;
use crate::geometry::Geometry;
use crate::h2::H2Matrix;
use crate::kernels::KernelFn;
use crate::solver::{BackendSpec, H2Solver, H2SolverBuilder};
use crate::util::Rng;
use std::fmt;

/// Point-distribution axis of a [`Case`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Distribution {
    /// Quasi-uniform Fibonacci sphere (the paper's §6.2 mesh).
    Sphere,
    /// Highly non-uniform blobs ([`Geometry::clustered`]) — the paper's
    /// load-imbalance regime.
    Clustered { clusters: usize },
}

impl Distribution {
    pub fn name(&self) -> &'static str {
        match self {
            Distribution::Sphere => "sphere",
            Distribution::Clustered { .. } => "clustered",
        }
    }
}

/// One randomized (or fixed) H² problem: everything needed to build the
/// matrix, its right-hand sides, and a facade session.
#[derive(Clone, Debug)]
pub struct Case {
    pub seed: u64,
    pub n: usize,
    pub leaf_size: usize,
    pub max_rank: usize,
    pub eta: f64,
    pub far_samples: usize,
    pub rhs_count: usize,
    /// Kernel name (resolvable through [`KernelFn::by_name`]).
    pub kernel: &'static str,
    pub distribution: Distribution,
}

impl fmt::Display for Case {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Case {{ seed: {}, n: {}, leaf: {}, rank: {}, eta: {}, far: {}, rhs: {}, kernel: {}, dist: {} }}",
            self.seed,
            self.n,
            self.leaf_size,
            self.max_rank,
            self.eta,
            self.far_samples,
            self.rhs_count,
            self.kernel,
            self.distribution.name()
        )
    }
}

impl Case {
    /// Structure fuzz: derive a varied problem from one seed — tree depth
    /// (via `n / leaf`), leaf size, rank budget, admissibility `eta`, RHS
    /// count, kernel, and point distribution all vary. Parameter ranges
    /// stay inside the SPD envelope (module docs), so every generated
    /// case factorizes.
    pub fn from_seed(seed: u64) -> Case {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0xC0FFEE));
        let leaf_size = [32, 48, 64][rng.below(3)];
        // 4..=12 leaves' worth of points: depth 2–4 once the tree splits.
        let leaves = 4 + rng.below(9);
        let n = leaf_size * leaves;
        let max_rank = [leaf_size / 2, (3 * leaf_size) / 4][rng.below(2)];
        let eta = [1.0, 1.5, 2.0][rng.below(3)];
        let rhs_count = 1 + rng.below(3);
        // New axes (PR 7) draw *after* the structural ones, so the
        // tree-shape corpus is a superset of the pre-existing sweep.
        let distribution = if rng.below(3) == 0 {
            Distribution::Clustered { clusters: 3 + rng.below(6) }
        } else {
            Distribution::Sphere
        };
        let kernel = match distribution {
            Distribution::Sphere => ["laplace", "yukawa", "matern32", "gaussian"][rng.below(4)],
            // Bounded kernels only: clustered points break the 1/r bound.
            Distribution::Clustered { .. } => ["gaussian", "matern32"][rng.below(2)],
        };
        Case {
            seed,
            n,
            leaf_size,
            max_rank,
            eta,
            far_samples: 0,
            rhs_count,
            kernel,
            distribution,
        }
    }

    /// The fixed fixture `device_api.rs` and `plan_replay.rs` share
    /// (leaf 64, rank 32, exact far field, default admissibility, sphere
    /// + laplace — `plan_verify.rs` pins this recorder layout by index).
    /// Override fields with struct-update syntax for variants.
    pub fn fixed(n: usize, seed: u64) -> Case {
        Case {
            seed,
            n,
            leaf_size: 64,
            max_rank: 32,
            eta: H2Config::default().eta,
            far_samples: 0,
            rhs_count: 1,
            kernel: "laplace",
            distribution: Distribution::Sphere,
        }
    }

    pub fn config(&self) -> H2Config {
        H2Config {
            leaf_size: self.leaf_size,
            max_rank: self.max_rank,
            eta: self.eta,
            far_samples: self.far_samples,
            ..Default::default()
        }
    }

    pub fn geometry(&self) -> Geometry {
        match self.distribution {
            Distribution::Sphere => Geometry::sphere_surface(self.n, self.seed),
            Distribution::Clustered { clusters } => {
                Geometry::clustered(self.n, clusters, self.seed)
            }
        }
    }

    pub fn kernel_fn(&self) -> KernelFn {
        KernelFn::by_name(self.kernel)
            .unwrap_or_else(|| panic!("unknown kernel {:?} in {self}", self.kernel))
    }

    /// Construct the H² matrix for this case.
    pub fn h2(&self) -> H2Matrix {
        H2Matrix::construct(&self.geometry(), &self.kernel_fn(), &self.config())
    }

    /// The `k`-th deterministic right-hand side of this case.
    pub fn rhs(&self, k: u64) -> Vec<f64> {
        rhs(self.n, self.seed.wrapping_mul(1000).wrapping_add(k))
    }

    /// All `rhs_count` right-hand sides.
    pub fn rhs_set(&self) -> Vec<Vec<f64>> {
        (0..self.rhs_count as u64).map(|k| self.rhs(k)).collect()
    }

    /// Build a facade session on `spec` (residual sampling off — parity /
    /// bench runs, not accuracy tests).
    pub fn solver(&self, spec: BackendSpec) -> H2Solver {
        H2SolverBuilder::new(self.geometry(), self.kernel_fn())
            .config(self.config())
            .backend(spec)
            .residual_samples(0)
            .build()
            .unwrap_or_else(|e| panic!("failed to build solver for {self}: {e}"))
    }
}

/// A deterministic normal right-hand side.
pub fn rhs(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

/// Seed sweep for the randomized harnesses: `0..H2_TEST_SEEDS` (default
/// 8). CI's stress jobs set `H2_TEST_SEEDS=16` to widen coverage.
pub fn sweep_seeds() -> Vec<u64> {
    let count = std::env::var("H2_TEST_SEEDS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(8);
    (0..count as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_seed_is_deterministic() {
        for seed in 0..32 {
            let a = Case::from_seed(seed);
            let b = Case::from_seed(seed);
            assert_eq!(a.to_string(), b.to_string());
            assert_eq!(a.n % a.leaf_size, 0, "{a}");
            assert!(a.max_rank >= a.leaf_size / 2, "{a}");
        }
    }

    #[test]
    fn clustered_cases_use_bounded_kernels_only() {
        let mut saw_clustered = false;
        let mut saw_new_kernel = false;
        for seed in 0..64 {
            let c = Case::from_seed(seed);
            if matches!(c.distribution, Distribution::Clustered { .. }) {
                saw_clustered = true;
                assert!(
                    matches!(c.kernel, "gaussian" | "matern32"),
                    "{c}: clustered + unbounded kernel is outside the SPD envelope"
                );
            }
            if matches!(c.kernel, "gaussian" | "matern32") {
                saw_new_kernel = true;
            }
            // Every drawn kernel must resolve.
            let _ = c.kernel_fn();
        }
        assert!(saw_clustered, "the sweep must cover the non-uniform regime");
        assert!(saw_new_kernel, "the sweep must cover kernels beyond laplace/yukawa");
    }

    #[test]
    fn fixed_pins_sphere_laplace() {
        let c = Case::fixed(256, 3);
        assert_eq!(c.kernel, "laplace");
        assert_eq!(c.distribution, Distribution::Sphere);
        assert_eq!(c.leaf_size, 64);
        assert_eq!(c.max_rank, 32);
    }

    #[test]
    fn geometry_matches_distribution() {
        let mut c = Case::fixed(128, 5);
        assert!(c.geometry().name.starts_with("sphere"));
        c.distribution = Distribution::Clustered { clusters: 4 };
        let g = c.geometry();
        assert!(g.name.starts_with("clustered"), "{}", g.name);
        assert_eq!(g.len(), 128);
    }
}
