//! Benchmark trajectory harness: a deterministic scenario matrix, a
//! runner that condenses each scenario into a [`RunReport`], and the
//! schema-versioned [`BenchReport`] that `BENCH_*.json` files persist.
//!
//! The matrix crosses the axes the paper's evaluation varies — execution
//! backend (`native` / `async:native` / `serial`), point distribution
//! (uniform sphere vs clustered blobs), kernel (singular and bounded),
//! and RHS width (single vs wide) — plus a structure-fuzz tail drawn
//! from [`cases::Case::from_seed`], the same generator the integration
//! tests sweep. Scenario *names* are stable identifiers: the comparator
//! ([`compare`]) matches previous trajectory files by name and is strict
//! on plan-derived counters (launches, FLOPs, peak bytes — deterministic
//! for a fixed structure) while treating wall times as noise unless a
//! threshold is given. The CLI `bench` subcommand and the CI
//! `bench-smoke` job are thin wrappers over this module.

pub mod cases;
pub mod compare;

use crate::metrics::run_trace::RunReport;
use crate::solver::{BackendSpec, H2Error};
use crate::util::json::{Json, JsonError};
use self::cases::{Case, Distribution};

/// Current `BENCH_*.json` schema version.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// Identifier this PR's trajectory file carries (`bench_id` field).
pub const BENCH_ID: &str = "BENCH_7";

/// Default output path for `h2ulv bench`, at the repo root.
pub const DEFAULT_OUTPUT: &str = "BENCH_7.json";

/// Seed shared by all base-matrix scenarios, so their geometries (and
/// therefore plans) are fixed and counter comparisons are exact.
const BASE_SEED: u64 = 7;

/// One named benchmark configuration: a backend spec name plus a
/// fully-specified problem [`Case`].
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Stable identifier (`backend/distribution-kernel/rhsW` or
    /// `backend/fuzz-S`) — the comparator's join key.
    pub name: String,
    /// Backend spec name, resolvable through [`BackendSpec::by_name`].
    pub backend: &'static str,
    pub case: Case,
}

/// Backends every sweep covers: the batched thread-pool backend, its
/// multi-stream overlapping wrapper, and the scalar reference.
pub const BACKENDS: [&str; 3] = ["native", "async:native", "serial"];

/// The deterministic scenario matrix for problem size `n`:
/// 3 backends × 3 (distribution, kernel) pairs × 2 RHS widths, plus one
/// structure-fuzz scenario per entry of `fuzz_seeds` on the native
/// backend. Enumeration order (and every name) is a pure function of the
/// arguments — pinned by a test, relied on by trajectory diffs.
pub fn scenario_matrix(n: usize, fuzz_seeds: &[u64]) -> Vec<Scenario> {
    let shapes: [(Distribution, &'static str); 3] = [
        (Distribution::Sphere, "laplace"),
        (Distribution::Sphere, "matern32"),
        (Distribution::Clustered { clusters: 6 }, "gaussian"),
    ];
    let mut out = Vec::new();
    for backend in BACKENDS {
        for &(distribution, kernel) in &shapes {
            for rhs_count in [1usize, 8] {
                let case = Case {
                    kernel,
                    distribution,
                    rhs_count,
                    ..Case::fixed(n, BASE_SEED)
                };
                out.push(Scenario {
                    name: format!("{backend}/{}-{kernel}/rhs{rhs_count}", distribution.name()),
                    backend,
                    case,
                });
            }
        }
    }
    for &seed in fuzz_seeds {
        out.push(Scenario {
            name: format!("native/fuzz-{seed}"),
            backend: "native",
            case: Case::from_seed(seed),
        });
    }
    out
}

/// Keep only scenarios whose name contains `filter` (empty = all).
pub fn filter_scenarios(scenarios: Vec<Scenario>, filter: &str) -> Vec<Scenario> {
    if filter.is_empty() {
        return scenarios;
    }
    scenarios.into_iter().filter(|s| s.name.contains(filter)).collect()
}

/// One scenario's result: the identifying axes plus the condensed
/// [`RunReport`] of a full build → factorize → solve-all-RHS run.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioReport {
    pub name: String,
    pub kernel: String,
    pub distribution: String,
    pub run: RunReport,
}

/// Build, factorize, and solve one scenario end to end, returning its
/// report. All `rhs_count` right-hand sides are solved (fanning out over
/// the session's workspace pool), so `run.solve_time` covers the whole
/// width and `run.rhs` equals it.
pub fn run_scenario(sc: &Scenario) -> Result<ScenarioReport, H2Error> {
    let spec = BackendSpec::by_name(sc.backend).ok_or_else(|| {
        H2Error::InvalidConfig(format!("unknown bench backend {:?}", sc.backend))
    })?;
    let solver = sc.case.solver(spec);
    solver.solve_many(&sc.case.rhs_set())?;
    Ok(ScenarioReport {
        name: sc.name.clone(),
        kernel: sc.case.kernel.to_string(),
        distribution: sc.case.distribution.name().to_string(),
        run: solver.run_report(),
    })
}

/// A full sweep: what one `BENCH_*.json` trajectory file holds.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub schema_version: u64,
    pub bench_id: String,
    /// Problem size the base matrix ran at.
    pub n: usize,
    pub scenarios: Vec<ScenarioReport>,
}

impl BenchReport {
    /// Wrap already-run scenario reports under the current schema.
    pub fn new(n: usize, scenarios: Vec<ScenarioReport>) -> BenchReport {
        BenchReport {
            schema_version: BENCH_SCHEMA_VERSION,
            bench_id: BENCH_ID.to_string(),
            n,
            scenarios,
        }
    }

    /// Run every scenario in order (failures abort the sweep — a bench
    /// case that cannot build is a bug, not a data point).
    pub fn collect(n: usize, scenarios: &[Scenario]) -> Result<BenchReport, H2Error> {
        Ok(Self::new(n, scenarios.iter().map(run_scenario).collect::<Result<Vec<_>, _>>()?))
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("schema_version".into(), Json::Num(self.schema_version as f64)),
            ("bench_id".into(), Json::Str(self.bench_id.clone())),
            ("n".into(), Json::Num(self.n as f64)),
            (
                "scenarios".into(),
                Json::Arr(
                    self.scenarios
                        .iter()
                        .map(|s| {
                            Json::Obj(vec![
                                ("name".into(), Json::Str(s.name.clone())),
                                ("kernel".into(), Json::Str(s.kernel.clone())),
                                ("distribution".into(), Json::Str(s.distribution.clone())),
                                ("run".into(), s.run.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_json(v: &Json) -> Result<BenchReport, JsonError> {
        let miss = |msg: &'static str| JsonError { pos: 0, msg };
        let scenarios = v
            .get("scenarios")
            .and_then(Json::as_arr)
            .ok_or(miss("scenarios"))?
            .iter()
            .map(|s| {
                Ok(ScenarioReport {
                    name: s
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or(miss("scenario name"))?
                        .to_string(),
                    kernel: s
                        .get("kernel")
                        .and_then(Json::as_str)
                        .ok_or(miss("scenario kernel"))?
                        .to_string(),
                    distribution: s
                        .get("distribution")
                        .and_then(Json::as_str)
                        .ok_or(miss("scenario distribution"))?
                        .to_string(),
                    run: RunReport::from_json(s.get("run").ok_or(miss("scenario run"))?)?,
                })
            })
            .collect::<Result<Vec<_>, JsonError>>()?;
        Ok(BenchReport {
            schema_version: v
                .get("schema_version")
                .and_then(Json::as_u64)
                .ok_or(miss("schema_version"))?,
            bench_id: v
                .get("bench_id")
                .and_then(Json::as_str)
                .ok_or(miss("bench_id"))?
                .to_string(),
            n: v.get("n").and_then(Json::as_usize).ok_or(miss("n"))?,
            scenarios,
        })
    }

    pub fn from_json_str(src: &str) -> Result<BenchReport, JsonError> {
        Self::from_json(&Json::parse(src)?)
    }

    /// One summary line per scenario (the CLI `bench` table body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{} (schema v{}, n {}): {} scenario(s)\n",
            self.bench_id,
            self.schema_version,
            self.n,
            self.scenarios.len()
        ));
        out.push_str(
            "scenario                            factor[ms] solve[ms]  launches  \
             waste%  overlap  peak[KB]\n",
        );
        for s in &self.scenarios {
            let r = &s.run;
            out.push_str(&format!(
                "{:<35} {:>9.3} {:>9.3} {:>9} {:>7.1} {:>8.3} {:>9.1}\n",
                s.name,
                1e3 * r.factor_time,
                1e3 * r.solve_time,
                r.factor_launches,
                1e2 * r.factor_padding_waste(),
                r.overlap_ratio,
                r.arena_peak_bytes as f64 / 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::run_trace::{LevelReport, RUN_REPORT_SCHEMA_VERSION};

    pub(super) fn sample_run(factor_flops: u64, factor_time: f64) -> RunReport {
        RunReport {
            schema_version: RUN_REPORT_SCHEMA_VERSION,
            backend: "native".to_string(),
            n: 256,
            depth: 2,
            rhs: 1,
            construct_time: 0.01,
            factor_time,
            solve_time: 0.002,
            factor_launches: 10,
            factor_flops,
            factor_padded_flops: factor_flops + factor_flops / 4,
            factor_levels: vec![LevelReport {
                level: 2,
                launches: 10,
                batch_items: 40,
                flops: factor_flops,
                padded_flops: factor_flops + factor_flops / 4,
            }],
            solve_levels: vec![],
            overlap_ratio: 0.0,
            overlapped_transfer_pairs: 0,
            solve_trace_events: 0,
            solve_overlap_ratio: 0.0,
            solve_overlapped_transfer_pairs: 0,
            arena_bytes: 1024,
            arena_peak_bytes: 2048,
            predicted_peak_bytes: 2048,
        }
    }

    pub(super) fn sample_bench() -> BenchReport {
        BenchReport {
            schema_version: BENCH_SCHEMA_VERSION,
            bench_id: BENCH_ID.to_string(),
            n: 256,
            scenarios: vec![
                ScenarioReport {
                    name: "native/sphere-laplace/rhs1".to_string(),
                    kernel: "laplace".to_string(),
                    distribution: "sphere".to_string(),
                    run: sample_run(1_000_000, 0.5),
                },
                ScenarioReport {
                    name: "serial/sphere-laplace/rhs1".to_string(),
                    kernel: "laplace".to_string(),
                    distribution: "sphere".to_string(),
                    run: sample_run(1_000_000, 2.0),
                },
            ],
        }
    }

    #[test]
    fn matrix_enumeration_is_deterministic() {
        let a = scenario_matrix(256, &[0, 1]);
        let b = scenario_matrix(256, &[0, 1]);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.case.to_string(), y.case.to_string());
        }
    }

    #[test]
    fn matrix_covers_required_axes() {
        let m = scenario_matrix(256, &[]);
        assert_eq!(m.len(), 18);
        let backends: std::collections::HashSet<_> = m.iter().map(|s| s.backend).collect();
        assert_eq!(backends.len(), 3, "3 backends required");
        let dists: std::collections::HashSet<_> =
            m.iter().map(|s| s.case.distribution.name()).collect();
        assert!(dists.len() >= 2, "2 distributions required");
        let widths: std::collections::HashSet<_> = m.iter().map(|s| s.case.rhs_count).collect();
        assert!(widths.len() >= 2, "2 RHS widths required");
        let kernels: std::collections::HashSet<_> = m.iter().map(|s| s.case.kernel).collect();
        assert!(kernels.len() >= 3, "kernels beyond laplace/yukawa required");
        // Names are unique — the comparator joins on them.
        let names: std::collections::HashSet<_> = m.iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), m.len());
    }

    #[test]
    fn fuzz_tail_appends_named_scenarios() {
        let m = scenario_matrix(256, &[3, 5]);
        assert_eq!(m.len(), 20);
        assert_eq!(m[18].name, "native/fuzz-3");
        assert_eq!(m[19].name, "native/fuzz-5");
    }

    #[test]
    fn filter_matches_substrings() {
        let m = scenario_matrix(256, &[]);
        let serial = filter_scenarios(m.clone(), "serial/");
        assert_eq!(serial.len(), 6);
        assert!(serial.iter().all(|s| s.backend == "serial"));
        assert_eq!(filter_scenarios(m.clone(), "").len(), m.len());
    }

    #[test]
    fn bench_report_round_trips_byte_stable() {
        let r = sample_bench();
        let once = r.to_json_string();
        let parsed = BenchReport::from_json_str(&once).unwrap();
        assert_eq!(parsed, r);
        assert_eq!(parsed.to_json_string(), once);
    }

    #[test]
    fn bench_report_rejects_missing_fields() {
        let mut j = sample_bench().to_json();
        if let Json::Obj(fields) = &mut j {
            fields.retain(|(k, _)| k != "bench_id");
        }
        assert!(BenchReport::from_json(&j).is_err());
    }

    #[test]
    fn render_lists_every_scenario() {
        let text = sample_bench().render();
        assert!(text.contains("native/sphere-laplace/rhs1"), "{text}");
        assert!(text.contains("serial/sphere-laplace/rhs1"), "{text}");
    }
}
