//! Hand-rolled CLI (clap is unavailable offline — DESIGN.md §10).
//!
//! ```text
//! h2ulv solve     [--n N] [--kernel K] [--geometry G] [--rank R] [--leaf L]
//!                 [--eta E] [--backend native|pjrt|pjrt:DIR|serial|async:INNER]
//!                 [--storage mirrored|device-only]
//!                 [--subst parallel|naive] [--ranks P]
//! h2ulv plan-dump [--n N] [--kernel K] [--geometry G] [--rank R] [--leaf L] [--eta E]
//!                 [--lint] [--exec BACKEND]
//! h2ulv plan-lint [--seeds S] [--json] | [--n N ...problem flags] [--json]
//! h2ulv bench     [--n N] [--fuzz S] [--scenarios FILTER] [--json]
//!                 [--out PATH|-] [--compare FILE] [--threshold X]
//!                 [--require-solve-overlap SUBSTR]
//! h2ulv figure    <12|13|16|17|18|20|21> [--full] [--out DIR]
//! h2ulv figures   [--full] [--out DIR]
//! h2ulv serve     [--tcp HOST:PORT] [--budget-bytes B] [--max-sessions S]
//!                 [--batch-window-ms W] [--threads T] [--timeout-ms D]
//! h2ulv serve-client --addr HOST:PORT [--shutdown]
//! h2ulv info
//! ```

use crate::construct::H2Config;
use crate::figures::{self, Scale};
use crate::geometry::Geometry;
use crate::kernels::KernelFn;
use crate::solver::{BackendSpec, FactorStorage, H2Error, H2SolverBuilder};
use crate::ulv::SubstMode;
use crate::util::Rng;

/// Parsed flag map: `--key value` pairs plus positional args.
pub struct Args {
    pub positional: Vec<String>,
    pub flags: std::collections::HashMap<String, String>,
}

/// Parse raw CLI args (everything after the subcommand).
pub fn parse_args(raw: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut it = raw.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = if it.peek().map(|s| !s.starts_with("--")).unwrap_or(false) {
                it.next().unwrap().clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), val);
        } else {
            positional.push(a.clone());
        }
    }
    Args { positional, flags }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

const USAGE: &str = "h2ulv — inherently parallel H²-ULV dense solver (Ma & Yokota, IJHPCA 2024)

USAGE:
  h2ulv solve   [--n N] [--kernel laplace|yukawa|gaussian|matern32]
                [--geometry sphere|cube|molecule] [--rank R] [--leaf L]
                [--eta E] [--backend native|pjrt|pjrt:DIR|serial|async:INNER]
                (async:INNER — e.g. async:native — overlaps level k+1's
                 uploads with level k's compute on multi-stream workers;
                 bit-identical results, prints the observed overlap)
                [--storage mirrored|device-only]
                (device-only keeps the factor resident on the device with
                 no host mirror — half the factor memory; mirrored is the
                 default)
                [--subst parallel|naive] [--ranks P] [--seed S] [--threads T]
                (--ranks P > 1 runs the real SPMD path: P thread-ranks,
                 each with its own device + rank-sharded arena, exchanging
                 buffers at the carved plan's Exchange instructions; prints
                 modeled α-β comm next to the measured exchange wall time.
                 --threads caps the solve_many worker fan-out; 0 = all cores)
  h2ulv plan-dump [--n N] [--kernel K] [--geometry G] [--rank R] [--leaf L]
                [--eta E] [--seed S] [--lint] [--ranks P] [--exec BACKEND]
                (record the execution plan only; print per-level launch
                 counts and padded-vs-useful FLOP ratios — no numerics.
                 --lint additionally runs the static verifier and prints
                 per-level critical-path / available-parallelism columns.
                 --ranks P > 1 additionally carves the plan for P ranks
                 and prints the cross-rank comm schedule (per-collective
                 buffer counts and delivered bytes).
                 --exec additionally replays the factorization on BACKEND
                 and prints the observed per-stream schedule: on
                 async:INNER backends this is the overlap evidence)
  h2ulv plan-lint [--seeds S] [--ranks P] [--json]
  h2ulv plan-lint --n N [--kernel K] [--geometry G] [--rank R] [--leaf L]
                [--eta E] [--seed S] [--ranks P] [--json]
                (statically verify recorded plans — dataflow lint, exact
                 peak-memory prediction, hazard-graph audit — for a sweep
                 of fuzzed structures (default; S from --seeds or
                 H2_TEST_SEEDS, else 8) or one explicit problem (--n).
                 Factorization and both substitution programs are checked;
                 with --ranks P > 1 each plan is also carved for P ranks
                 and the cross-rank audit (per-rank dataflow, send/recv
                 matching, collective-count agreement) must pass;
                 exit 1 on any violation. --json emits machine-readable
                 reports)
  h2ulv bench   [--n N] [--fuzz S] [--scenarios FILTER] [--json]
                [--out PATH|-] [--compare FILE] [--threshold X]
                [--require-solve-overlap SUBSTR]
                (run the benchmark trajectory sweep: 3 backends × sphere/
                 clustered distributions × single/wide RHS, plus S
                 structure-fuzz scenarios (default from H2_TEST_SEEDS,
                 else 8). Writes the schema-versioned trajectory JSON to
                 PATH (default BENCH_7.json; '-' skips the file).
                 --scenarios keeps only names containing FILTER.
                 --compare diffs against a previous trajectory file:
                 plan-derived counters (launches, FLOPs, peak bytes) gate
                 strictly, wall times only beyond relative --threshold
                 (default 0 = report-only); exit 1 on any regression.
                 --require-solve-overlap gates that at least one scenario
                 whose name contains SUBSTR reports a nonzero solve-path
                 overlap ratio — the CI proof that substitution pipelines
                 through the async engine; exit 1 otherwise)
  h2ulv figure  <12|13|16|17|18|20|21> [--full] [--out DIR]
  h2ulv figures [--full] [--out DIR]
  h2ulv serve   [--tcp HOST:PORT] [--budget-bytes B] [--max-sessions S]
                [--batch-window-ms W] [--threads T] [--timeout-ms D]
                (multi-tenant solve service: line-oriented JSON requests
                 over stdin/stdout, or a TCP accept loop with --tcp.
                 Same-config builds share one cached, factorized session
                 (LRU-evicted under the resident-byte budget B); queued
                 single-RHS solves are coalesced into one solve_many
                 within the W-millisecond batching window; T bounds the
                 global solve-worker fan-out (0 = all cores); D is the
                 default per-request timeout (0 = none))
  h2ulv serve-client --addr HOST:PORT [--shutdown]
                (scripted smoke client for a running serve --tcp: two
                 tenants, mixed solve/solve_many traffic, asserts
                 cache sharing, micro-batch coalescing, and bit-identical
                 batched-vs-direct solutions; --shutdown stops the server)
  h2ulv info
";

/// CLI entry point; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    if argv.is_empty() {
        print!("{USAGE}");
        return 2;
    }
    let cmd = argv[0].clone();
    let args = parse_args(&argv[1..]);
    match cmd.as_str() {
        "solve" => cmd_solve(&args),
        "plan-dump" => cmd_plan_dump(&args),
        "plan-lint" => cmd_plan_lint(&args),
        "bench" => cmd_bench(&args),
        "figure" => cmd_figure(&args),
        "figures" => cmd_figures(&args),
        "serve" => cmd_serve(&args),
        "serve-client" => cmd_serve_client(&args),
        "info" => cmd_info(),
        _ => {
            eprintln!("unknown command: {cmd}\n{USAGE}");
            2
        }
    }
}

fn make_geometry(name: &str, n: usize, seed: u64) -> Geometry {
    // Unknown names fall back to the sphere (the serve protocol rejects
    // them instead — see `BuildParams::build_solver`).
    Geometry::by_name(name, n, seed).unwrap_or_else(|| Geometry::sphere_surface(n, seed))
}

/// Problem setup shared by `solve` and `plan-dump`: same flags, same
/// defaults, so a dumped schedule always describes the problem `solve`
/// would run.
fn problem_from_args(args: &Args) -> (usize, u64, KernelFn, Geometry, H2Config) {
    let n = args.usize_or("n", 4096);
    let seed = args.usize_or("seed", 42) as u64;
    let kernel = KernelFn::by_name(args.get("kernel").unwrap_or("laplace"))
        .unwrap_or_else(KernelFn::laplace);
    let g = make_geometry(args.get("geometry").unwrap_or("sphere"), n, seed);
    let cfg = H2Config {
        leaf_size: args.usize_or("leaf", 64),
        max_rank: args.usize_or("rank", 32),
        eta: args.f64_or("eta", 1.0),
        far_samples: args.usize_or("far-samples", 128),
        near_samples: args.usize_or("near-samples", 96),
        ..Default::default()
    };
    (n, seed, kernel, g, cfg)
}

fn cmd_solve(args: &Args) -> i32 {
    let (n, seed, kernel, g, cfg) = problem_from_args(args);
    let subst = match args.get("subst") {
        Some("naive") => SubstMode::Naive,
        _ => SubstMode::Parallel,
    };
    let spec = match args.get("backend") {
        None => BackendSpec::Native,
        Some(name) => match BackendSpec::by_name(name) {
            Some(s) => s,
            None => {
                eprintln!("unknown backend: {name}\n{USAGE}");
                return 2;
            }
        },
    };
    let storage = match args.get("storage") {
        None => FactorStorage::Mirrored,
        Some(name) => match FactorStorage::by_name(name) {
            Some(s) => s,
            None => {
                eprintln!("unknown storage mode: {name}\n{USAGE}");
                return 2;
            }
        },
    };
    println!(
        "h2ulv solve: N={n} kernel={} geometry={} leaf={} rank={} eta={} storage={}",
        kernel.name,
        g.name,
        cfg.leaf_size,
        cfg.max_rank,
        cfg.eta,
        storage.name()
    );

    let builder = H2SolverBuilder::new(g, kernel)
        .config(cfg)
        .backend(spec)
        .subst_mode(subst)
        .factor_storage(storage)
        .residual_samples(128)
        .max_solve_threads(args.usize_or("threads", 0));
    // PJRT artifacts missing is a soft failure on the CLI: warn + native.
    let solver = match builder.clone().build() {
        Ok(s) => s,
        Err(H2Error::BackendUnavailable { backend, reason }) => {
            eprintln!("{backend} backend unavailable ({reason}); falling back to native.");
            match builder.backend(BackendSpec::Native).build() {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("h2ulv solve: {e}");
                    return 1;
                }
            }
        }
        Err(e) => {
            eprintln!("h2ulv solve: {e}");
            return 1;
        }
    };
    let stats = solver.stats();
    println!(
        "construct: {:.3}s  storage {:.1} MB (dense would be {:.1} MB)",
        stats.construct_time,
        stats.h2_entries as f64 * 8.0 / 1e6,
        (n * n) as f64 * 8.0 / 1e6
    );

    let mut rng = Rng::new(seed ^ 0x5EED);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    let ranks = args.usize_or("ranks", 1);
    if ranks > 1 {
        match solver.solve_dist(&b, ranks) {
            Ok(rep) => {
                println!(
                    "distributed P={}: thread-ranks on rank-sharded arenas (one {} device per rank)",
                    rep.ranks,
                    solver.backend_name()
                );
                let m = &rep.measured;
                println!(
                    "  factor: modeled {:.4}s / {:.1} KB (NCCL-like α-β) | measured {} collective(s), {:.1} KB sent, {:.4}s exchange wall",
                    rep.factor_time,
                    rep.factor_bytes as f64 / 1e3,
                    m.factor.exchanges,
                    m.factor.bytes as f64 / 1e3,
                    m.factor.seconds
                );
                println!(
                    "  subst:  modeled {:.4}s / {:.1} KB (NCCL-like α-β) | measured {} collective(s), {:.1} KB sent, {:.4}s exchange wall",
                    rep.subst_time,
                    rep.subst_bytes as f64 / 1e3,
                    m.subst.exchanges,
                    m.subst.bytes as f64 / 1e3,
                    m.subst.seconds
                );
                println!("  sampled residual |Ax-b|/|b| = {:.3e}", rep.residual.unwrap_or(f64::NAN));
                return 0;
            }
            Err(e) => {
                eprintln!("h2ulv solve: {e}");
                return 1;
            }
        }
    }

    println!(
        "factorize[{}]: {:.3}s ({:.2} GFLOP, {:.2} GFLOP/s)",
        solver.backend_name(),
        stats.factor_time,
        stats.factor_flops as f64 / 1e9,
        stats.factor_flops as f64 / stats.factor_time / 1e9
    );
    println!(
        "factor resident: {:.1} MB device arena (peak {:.1} MB) + {:.1} MB host mirror",
        stats.arena_bytes as f64 / 1e6,
        stats.arena_peak_bytes as f64 / 1e6,
        8.0 * stats.mirror_entries as f64 / 1e6
    );
    if let Some(trace) = &stats.overlap {
        print!("{}", trace.render());
    }
    match solver.solve(&b) {
        Ok(rep) => {
            println!("substitute[{subst:?}]: {:.4}s", rep.subst_time);
            println!("sampled residual |Ax-b|/|b| = {:.3e}", rep.residual.unwrap_or(f64::NAN));
            0
        }
        Err(e) => {
            eprintln!("h2ulv solve: {e}");
            1
        }
    }
}

/// Run the multi-tenant solve service: line-oriented JSON over
/// stdin/stdout by default, or a TCP accept loop with `--tcp HOST:PORT`
/// (`:0` picks a free port; the chosen address is printed as
/// `listening on ADDR` so scripted clients can connect).
fn cmd_serve(args: &Args) -> i32 {
    let cfg = crate::serve::ServeConfig {
        budget_bytes: args.usize_or("budget-bytes", 256 << 20),
        max_sessions: args.usize_or("max-sessions", 8),
        batch_window_ms: args.usize_or("batch-window-ms", 2) as u64,
        worker_budget: args.usize_or("threads", 0),
        timeout_ms: args.usize_or("timeout-ms", 0) as u64,
        idle_keep_workspaces: args.usize_or("idle-workspaces", 1),
    };
    let service = crate::serve::Service::new(cfg);
    match args.get("tcp") {
        Some(addr) => {
            let listener = match service.bind_tcp(addr) {
                Ok(l) => l,
                Err(e) => {
                    eprintln!("h2ulv serve: cannot bind {addr}: {e}");
                    return 1;
                }
            };
            let bound = service.bound_addr().expect("bind_tcp records the address");
            println!("h2ulv serve: listening on {bound}");
            match service.serve_tcp(listener) {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("h2ulv serve: {e}");
                    1
                }
            }
        }
        None => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            match service.serve_stream(stdin.lock(), stdout.lock()) {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("h2ulv serve: {e}");
                    1
                }
            }
        }
    }
}

/// Drive the scripted smoke client against a running `serve --tcp`
/// instance (see [`crate::serve::service::run_smoke_client`]): exit 0 only
/// if cache sharing, micro-batch coalescing, and batched-vs-direct
/// bit-identity all held.
fn cmd_serve_client(args: &Args) -> i32 {
    let Some(addr) = args.get("addr") else {
        eprintln!("serve-client requires --addr HOST:PORT\n{USAGE}");
        return 2;
    };
    let shutdown = args.get("shutdown").is_some();
    match crate::serve::service::run_smoke_client(addr, shutdown) {
        Ok(()) => {
            println!("h2ulv serve-client: ok");
            0
        }
        Err(e) => {
            eprintln!("h2ulv serve-client: {e}");
            1
        }
    }
}

/// Record the execution plan for a problem and print its schedule: the
/// per-level launch counts and padded-vs-useful FLOP ratios come straight
/// from the IR — no factorization (and no kernel numerics beyond H²
/// construction) runs. With `--exec BACKEND` the factorization program is
/// additionally replayed on that backend and the observed per-stream
/// schedule is printed — on `async:<inner>` backends that is the
/// upload/compute overlap evidence.
fn cmd_plan_dump(args: &Args) -> i32 {
    let (n, _seed, kernel, g, cfg) = problem_from_args(args);
    if let Err(e) = crate::solver::builder::validate(&g, &cfg) {
        eprintln!("h2ulv plan-dump: {e}");
        return 1;
    }
    println!(
        "h2ulv plan-dump: N={n} kernel={} geometry={} leaf={} rank={} eta={}",
        kernel.name, g.name, cfg.leaf_size, cfg.max_rank, cfg.eta
    );
    let built = crate::solver::guard("planning", || {
        let h2 = crate::h2::H2Matrix::construct(&g, &kernel, &cfg);
        let plan = crate::plan::record(&h2);
        (h2, plan)
    });
    let (h2, plan) = match built {
        Ok(pair) => pair,
        Err(e) => {
            eprintln!("h2ulv plan-dump: {e}");
            return 1;
        }
    };
    print!("{}", plan.render_schedule());
    if args.get("lint").is_some() {
        // Lint both substitution programs, then fold the static hazard
        // graph into a per-level table next to the launch/FLOP columns.
        plan.solve_program(SubstMode::Naive);
        let report = match crate::plan::verify::verify(&plan) {
            Ok(r) => r,
            Err(v) => {
                eprintln!("h2ulv plan-dump: {v}");
                return 1;
            }
        };
        let stats = plan.schedule_stats();
        println!(
            "\nstatic lint (level, ops, crit_path, parallelism, launches, useful_gflop, waste):"
        );
        for lh in &report.hazard.levels {
            let (launches, gflop, waste) = stats
                .factor_levels
                .get(lh.level)
                .map(|s| {
                    let waste = if s.padded_flops > 0 {
                        100.0 * (1.0 - s.flops as f64 / s.padded_flops as f64)
                    } else {
                        0.0
                    };
                    (s.launches, s.flops as f64 / 1e9, waste)
                })
                .unwrap_or((0, 0.0, 0.0));
            let label = if lh.level == usize::MAX {
                "pre".to_string()
            } else {
                format!("L{}", lh.level)
            };
            println!(
                "  {label:<4} {:>5} {:>9} {:>11.2} {:>8} {:>12.4} {:>6.1}%",
                lh.ops, lh.critical_path, lh.parallelism, launches, gflop, waste
            );
        }
        print!("{}", report.render());
    }
    let ranks = args.usize_or("ranks", 1);
    if ranks > 1 {
        // Carve the plan for a thread-rank group and print the comm
        // schedule — Exchange instructions are ordinary plan IR, so the
        // whole distributed schedule is visible statically.
        let rps = crate::plan::carve(&plan, ranks, SubstMode::Parallel);
        print!("{}", crate::plan::render_comm(&rps));
    }
    if let Some(name) = args.get("exec") {
        let Some(spec) = BackendSpec::by_name(name) else {
            eprintln!("unknown backend: {name}\n{USAGE}");
            return 2;
        };
        let device = match spec.instantiate() {
            Ok(d) => d,
            Err(e) => {
                eprintln!("h2ulv plan-dump: {e}");
                return 1;
            }
        };
        let plan = std::sync::Arc::new(plan);
        println!("replaying factorization on {} ...", device.name());
        let replay = crate::solver::guard("factorization", || {
            crate::plan::Executor::new(device.as_ref()).factorize_device_only(&plan, &h2)
        });
        if let Err(e) = replay {
            eprintln!("h2ulv plan-dump: {e}");
            return 1;
        }
        match device.take_overlap_trace() {
            Some(trace) => print!("{}", trace.render()),
            None => println!("backend '{}' is synchronous — no overlap trace", device.name()),
        }
    }
    0
}

/// One structure-fuzz problem for `plan-lint` — the library's canonical
/// generator ([`crate::bench::cases::Case::from_seed`]), so a CLI seed
/// reproduces the exact structure (and distribution and kernel) a failing
/// test or bench scenario names.
fn fuzz_case(seed: u64) -> crate::bench::cases::Case {
    crate::bench::cases::Case::from_seed(seed)
}

/// Record and statically verify the plan for one problem. The lazy naive
/// substitution program is forced first so both modes are linted. With
/// `ranks > 1` the plan is additionally carved for that thread-rank group
/// and the cross-rank audit runs on the carved set (per-rank dataflow,
/// collective-count agreement, send/recv matching).
fn lint_problem(
    g: &Geometry,
    kernel: &KernelFn,
    cfg: &H2Config,
    ranks: usize,
) -> Result<
    Result<
        (crate::plan::PlanReport, Option<crate::plan::verify::RankSetReport>),
        crate::plan::PlanViolation,
    >,
    H2Error,
> {
    crate::solver::guard("planning", || {
        let h2 = crate::h2::H2Matrix::construct(g, kernel, cfg);
        let plan = crate::plan::record(&h2);
        let _ = plan.solve_program(SubstMode::Naive);
        let report = match crate::plan::verify::verify(&plan) {
            Ok(r) => r,
            Err(v) => return Err(v),
        };
        if ranks > 1 {
            match crate::plan::verify::verify_carved(&plan, ranks, SubstMode::Parallel) {
                Ok(rs) => Ok((report, Some(rs))),
                Err(v) => Err(v),
            }
        } else {
            Ok((report, None))
        }
    })
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn report_json(r: &crate::plan::PlanReport) -> String {
    let levels: Vec<String> = r
        .hazard
        .levels
        .iter()
        .map(|l| {
            let level = if l.level == usize::MAX { -1 } else { l.level as i64 };
            format!(
                "{{\"level\":{level},\"ops\":{},\"critical_path\":{},\"parallelism\":{:.3}}}",
                l.ops, l.critical_path, l.parallelism
            )
        })
        .collect();
    let solve = |s: &crate::plan::verify::SolveProgramReport| {
        format!(
            "{{\"instrs\":{},\"launches\":{},\"workspace_bytes\":{}}}",
            s.instrs, s.launches, s.workspace_bytes
        )
    };
    format!(
        "{{\"n\":{},\"depth\":{},\"factor_instrs\":{},\"predicted_peak_bytes\":{},\
         \"resident_bytes\":{},\"resident_buffers\":{},\
         \"hazard\":{{\"streams\":{},\"ops\":{},\"edges\":{},\"critical_path\":{},\
         \"levels\":[{}]}},\"solve_parallel\":{},\"solve_naive\":{}}}",
        r.n,
        r.depth,
        r.factor_instrs,
        r.predicted_peak_bytes,
        r.resident_bytes,
        r.resident_buffers,
        r.hazard.streams,
        r.hazard.ops.len(),
        r.hazard.edges,
        r.hazard.critical_path,
        levels.join(","),
        solve(&r.solve_parallel),
        r.solve_naive.as_ref().map(solve).unwrap_or_else(|| "null".to_string()),
    )
}

fn rank_set_json(rs: &crate::plan::verify::RankSetReport) -> String {
    format!(
        "{{\"ranks\":{},\"factor_collectives\":{},\"solve_collectives\":{},\
         \"factor_comm_bytes\":{},\"solve_comm_bytes\":{}}}",
        rs.ranks,
        rs.factor_collectives,
        rs.solve_collectives,
        rs.factor_comm_bytes,
        rs.solve_comm_bytes
    )
}

fn violation_json(v: &crate::plan::PlanViolation) -> String {
    format!(
        "{{\"program\":\"{}\",\"index\":{},\"opcode\":\"{}\",\"buffer\":{},\
         \"kind\":\"{}\",\"detail\":\"{}\"}}",
        v.program,
        v.index,
        json_escape(v.opcode),
        v.buffer.map(|b| b.0.to_string()).unwrap_or_else(|| "null".to_string()),
        v.kind,
        json_escape(&v.detail),
    )
}

/// Statically verify recorded plans: dataflow lint, exact peak-memory
/// prediction, and the hazard-graph audit (see [`crate::plan::verify`]).
/// Default: a structure-fuzz sweep over `--seeds`/`H2_TEST_SEEDS` seeds.
/// With `--n`, lints the single problem the other flags describe (same
/// flags as `plan-dump`). Exits 1 on any violation.
fn cmd_plan_lint(args: &Args) -> i32 {
    let json = args.get("json").is_some();
    if args.get("n").is_some() {
        let (n, _seed, kernel, g, cfg) = problem_from_args(args);
        if let Err(e) = crate::solver::builder::validate(&g, &cfg) {
            eprintln!("h2ulv plan-lint: {e}");
            return 1;
        }
        if !json {
            println!(
                "h2ulv plan-lint: N={n} kernel={} geometry={} leaf={} rank={} eta={}",
                kernel.name, g.name, cfg.leaf_size, cfg.max_rank, cfg.eta
            );
        }
        return match lint_problem(&g, &kernel, &cfg, args.usize_or("ranks", 1)) {
            Ok(Ok((report, rank_set))) => {
                if json {
                    match &rank_set {
                        Some(rs) => println!(
                            "{{\"ok\":true,\"report\":{},\"rank_set\":{}}}",
                            report_json(&report),
                            rank_set_json(rs)
                        ),
                        None => println!("{{\"ok\":true,\"report\":{}}}", report_json(&report)),
                    }
                } else {
                    print!("{}", report.render());
                    if let Some(rs) = &rank_set {
                        println!(
                            "rank-set audit P={}: ok — {} factor / {} solve collective(s), \
                             {} B / {} B delivered",
                            rs.ranks,
                            rs.factor_collectives,
                            rs.solve_collectives,
                            rs.factor_comm_bytes,
                            rs.solve_comm_bytes
                        );
                    }
                }
                0
            }
            Ok(Err(v)) => {
                if json {
                    println!("{{\"ok\":false,\"violation\":{}}}", violation_json(&v));
                } else {
                    eprintln!("h2ulv plan-lint: {v}");
                }
                1
            }
            Err(e) => {
                eprintln!("h2ulv plan-lint: {e}");
                1
            }
        };
    }

    // Structure-fuzz sweep (the CI gate).
    let count = args
        .get("seeds")
        .and_then(|s| s.parse::<u64>().ok())
        .or_else(|| {
            std::env::var("H2_TEST_SEEDS").ok().and_then(|s| s.parse::<u64>().ok())
        })
        .unwrap_or(8);
    let ranks = args.usize_or("ranks", 1);
    let mut rows = Vec::new();
    let mut failures = 0usize;
    for seed in 0..count {
        let case = fuzz_case(seed);
        let g = case.geometry();
        let cfg = case.config();
        let head = format!(
            "\"seed\":{},\"n\":{},\"leaf\":{},\"rank\":{},\"eta\":{},\"kernel\":\"{}\",\
             \"distribution\":\"{}\"",
            case.seed,
            case.n,
            case.leaf_size,
            case.max_rank,
            case.eta,
            case.kernel,
            case.distribution.name()
        );
        match lint_problem(&g, &case.kernel_fn(), &cfg, ranks) {
            Ok(Ok((report, rank_set))) => {
                if json {
                    let rs_field = rank_set
                        .as_ref()
                        .map(|rs| format!(",\"rank_set\":{}", rank_set_json(rs)))
                        .unwrap_or_default();
                    rows.push(format!(
                        "{{{head},\"ok\":true,\"report\":{}{rs_field}}}",
                        report_json(&report)
                    ));
                } else {
                    let rs_note = rank_set
                        .as_ref()
                        .map(|rs| {
                            format!(
                                ", P={} comm ok ({} collectives)",
                                rs.ranks,
                                rs.factor_collectives + rs.solve_collectives
                            )
                        })
                        .unwrap_or_default();
                    println!(
                        "seed {:>2}: N={:<5} leaf={} rank={:<2} eta={} {}/{} — ok: peak {} B, \
                         {} ops / {} edges, crit path {}, parallelism {:.1}{rs_note}",
                        case.seed,
                        case.n,
                        case.leaf_size,
                        case.max_rank,
                        case.eta,
                        case.distribution.name(),
                        case.kernel,
                        report.predicted_peak_bytes,
                        report.hazard.ops.len(),
                        report.hazard.edges,
                        report.hazard.critical_path,
                        if report.hazard.critical_path > 0 {
                            report.hazard.ops.len() as f64 / report.hazard.critical_path as f64
                        } else {
                            0.0
                        },
                    );
                }
            }
            Ok(Err(v)) => {
                failures += 1;
                if json {
                    rows.push(format!("{{{head},\"ok\":false,\"violation\":{}}}", violation_json(&v)));
                } else {
                    eprintln!("seed {}: VIOLATION — {v}", case.seed);
                }
            }
            Err(e) => {
                failures += 1;
                if json {
                    rows.push(format!(
                        "{{{head},\"ok\":false,\"error\":\"{}\"}}",
                        json_escape(&e.to_string())
                    ));
                } else {
                    eprintln!("seed {}: ERROR — {e}", case.seed);
                }
            }
        }
    }
    if json {
        println!(
            "{{\"seeds\":{count},\"failures\":{failures},\"results\":[{}]}}",
            rows.join(",")
        );
    } else {
        println!(
            "plan-lint: {count} fuzzed structures, {failures} failure(s) \
             (factorization + both substitution programs verified per structure)"
        );
    }
    if failures > 0 {
        1
    } else {
        0
    }
}

/// Run the benchmark trajectory sweep and (optionally) diff it against a
/// previous `BENCH_*.json`. See [`crate::bench`] for the scenario matrix
/// and the comparator's strict-counters / loose-times policy.
fn cmd_bench(args: &Args) -> i32 {
    use crate::bench::{self, BenchReport};
    let n = args.usize_or("n", 768);
    let filter = args.get("scenarios").unwrap_or("");
    let threshold = args.f64_or("threshold", 0.0);
    let fuzz_seeds: Vec<u64> = match args.get("fuzz") {
        Some(s) => match s.parse::<u64>() {
            Ok(count) => (0..count).collect(),
            Err(_) => {
                eprintln!("--fuzz expects a seed count, got {s:?}\n{USAGE}");
                return 2;
            }
        },
        None => bench::cases::sweep_seeds(),
    };
    let scenarios = bench::filter_scenarios(bench::scenario_matrix(n, &fuzz_seeds), filter);
    if scenarios.is_empty() {
        eprintln!("h2ulv bench: no scenarios match filter {filter:?}");
        return 2;
    }
    let json = args.get("json").is_some();
    let mut results = Vec::new();
    for sc in &scenarios {
        if !json {
            println!("running {} ({}) ...", sc.name, sc.case);
        }
        match bench::run_scenario(sc) {
            Ok(r) => results.push(r),
            Err(e) => {
                eprintln!("h2ulv bench: {}: {e}", sc.name);
                return 1;
            }
        }
    }
    let report = BenchReport::new(n, results);
    let text = report.to_json_string();
    if json {
        println!("{text}");
    } else {
        print!("{}", report.render());
    }
    let out = args.get("out").unwrap_or(bench::DEFAULT_OUTPUT);
    if out != "-" {
        if let Err(e) = std::fs::write(out, format!("{text}\n")) {
            eprintln!("h2ulv bench: cannot write {out}: {e}");
            return 1;
        }
        if !json {
            println!("wrote {out}");
        }
    }
    if let Some(path) = args.get("compare") {
        let prev = match std::fs::read_to_string(path) {
            Ok(src) => match BenchReport::from_json_str(&src) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("h2ulv bench: {path} is not a trajectory file: {e}");
                    return 1;
                }
            },
            Err(e) => {
                eprintln!("h2ulv bench: cannot read {path}: {e}");
                return 1;
            }
        };
        let cmp = bench::compare::compare(&prev, &report, threshold);
        print!("{}", cmp.render());
        if cmp.has_regressions() {
            eprintln!(
                "h2ulv bench: {} regression(s) vs {path} (threshold {threshold})",
                cmp.regressions().len()
            );
            return 1;
        }
        println!("no regressions vs {path}");
    }
    if let Some(substr) = args.get("require-solve-overlap") {
        let matching: Vec<_> =
            report.scenarios.iter().filter(|s| s.name.contains(substr)).collect();
        if matching.is_empty() {
            eprintln!(
                "h2ulv bench: --require-solve-overlap {substr:?} matches no scenario in this sweep"
            );
            return 2;
        }
        let overlapped = matching.iter().filter(|s| s.run.solve_overlap_ratio > 0.0).count();
        if overlapped == 0 {
            eprintln!(
                "h2ulv bench: no scenario matching {substr:?} reported solve-path overlap \
                 ({} checked) — substitution is not pipelining through the async engine",
                matching.len()
            );
            return 1;
        }
        if !json {
            println!(
                "solve-path overlap gate: {overlapped}/{} scenario(s) matching {substr:?} \
                 overlapped",
                matching.len()
            );
        }
    }
    0
}

fn cmd_figure(args: &Args) -> i32 {
    let scale = if args.get("full").is_some() { Scale::Full } else { Scale::Quick };
    let Some(which) = args.positional.first() else {
        eprintln!("figure number required\n{USAGE}");
        return 2;
    };
    let report = match which.as_str() {
        "12" => figures::fig12(scale),
        "13" | "14" | "15" => figures::fig13_14_15(scale),
        "16" => figures::fig16(scale),
        "17" => figures::fig17(scale),
        "18" | "19" => figures::fig18_19(scale),
        "20" => figures::fig20(scale),
        "21" | "22" | "23" => figures::fig21_22_23(scale),
        other => {
            eprintln!("unknown figure {other}");
            return 2;
        }
    };
    println!("{report}");
    if let Some(dir) = args.get("out") {
        let dir = std::path::Path::new(dir);
        std::fs::create_dir_all(dir).ok();
        std::fs::write(dir.join(format!("fig{which}.txt")), &report).ok();
    }
    0
}

fn cmd_figures(args: &Args) -> i32 {
    let scale = if args.get("full").is_some() { Scale::Full } else { Scale::Quick };
    let out_dir = args.get("out").map(std::path::Path::new);
    let all = figures::run_all(scale, out_dir);
    println!("{all}");
    0
}

fn cmd_info() -> i32 {
    println!(
        "h2ulv {} — H²-ULV factorization (Ma & Yokota, IJHPCA 2024 reproduction)",
        env!("CARGO_PKG_VERSION")
    );
    println!("threads: {}", crate::util::pool::num_threads());
    let artifacts = std::path::Path::new("artifacts/manifest.json");
    if artifacts.exists() {
        match crate::runtime::Manifest::load(std::path::Path::new("artifacts")) {
            Ok(m) => println!(
                "artifacts: {} executables, families {:?}, buckets {:?}",
                m.index.len(),
                m.families,
                m.buckets
            ),
            Err(e) => println!("artifacts: manifest unreadable: {e}"),
        }
    } else {
        println!("artifacts: missing (run `make artifacts` for the PJRT backend)");
    }
    match xla::PjRtClient::cpu() {
        Ok(c) => println!("pjrt: {} ({} device(s))", c.platform_name(), c.device_count()),
        Err(e) => println!("pjrt: unavailable: {e}"),
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        parse_args(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parse_flags_and_positional() {
        let a = args(&["18", "--out", "dir", "--full"]);
        assert_eq!(a.positional, vec!["18"]);
        assert_eq!(a.get("out"), Some("dir"));
        assert_eq!(a.get("full"), Some("true"));
        assert_eq!(a.usize_or("n", 7), 7);
    }

    #[test]
    fn numeric_parsing() {
        let a = args(&["--n", "512", "--eta", "1.5"]);
        assert_eq!(a.usize_or("n", 0), 512);
        assert!((a.f64_or("eta", 0.0) - 1.5).abs() < 1e-12);
    }
}
