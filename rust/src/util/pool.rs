//! Data-parallel helpers over OS threads.
//!
//! `rayon` is unavailable offline, so batched native execution uses scoped
//! `std::thread` fan-out. Work is split into contiguous chunks (one per
//! worker) which is the right granularity for our batched-kernel workloads:
//! each item is already a dense matrix operation, so per-item stealing is
//! unnecessary.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (overridable with `H2ULV_THREADS`).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("H2ULV_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Run `f(i)` for every `i in 0..n`, in parallel across worker threads.
///
/// `f` must be `Sync` (called concurrently from many threads). Items are
/// distributed by an atomic cursor over fixed-size chunks so mildly
/// imbalanced workloads (variable block ranks) still level out.
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    // Chunked dynamic scheduling: grab `chunk` items at a time.
    let chunk = (n / (workers * 4)).max(1);
    let cursor = AtomicUsize::new(0);
    // Workers must credit FLOPs to the same ambient scope as the
    // coordinator (flops::add is thread-local).
    let ambient = crate::metrics::flops::ambient();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let cursor = &cursor;
            let f = &f;
            let ambient = &ambient;
            s.spawn(move || {
                let _guard = crate::metrics::flops::bind_ambient(ambient.clone());
                loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        f(i);
                    }
                }
            });
        }
    });
}

/// Parallel map preserving order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = out.as_mut_slice();
        // SAFETY-free approach: use interior chunking via raw split.
        // We avoid unsafe by collecting through a Mutex-free trick:
        // give each worker disjoint indices through an atomic cursor and
        // write through a raw pointer wrapper.
        struct Ptr<T>(*mut Option<T>);
        unsafe impl<T: Send> Sync for Ptr<T> {}
        let ptr = Ptr(slots.as_mut_ptr());
        let ptr_ref = &ptr;
        par_for(n, move |i| {
            let v = f(i);
            // SAFETY: each index i is visited exactly once across all
            // workers (atomic cursor in par_for), so writes are disjoint.
            unsafe {
                *ptr_ref.0.add(i) = Some(v);
            }
        });
    }
    out.into_iter().map(|x| x.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_visits_all_once() {
        let n = 1000;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_for(n, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_order() {
        let out = par_map(257, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_for_propagates_flop_scope() {
        use crate::metrics::flops::{self, FlopScope, Phase};
        let scope = FlopScope::new();
        flops::scoped(&scope, Phase::Factor, || {
            par_for(64, |_| flops::add(1));
        });
        assert_eq!(scope.snapshot().factor, 64);
    }

    #[test]
    fn par_for_empty_and_one() {
        par_for(0, |_| panic!("should not run"));
        let hit = AtomicU64::new(0);
        par_for(1, |_| {
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }
}
