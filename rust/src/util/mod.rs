//! Small utilities: PRNG, thread pool, hand-rolled property-test harness.
//!
//! The container has no offline access to `rand`, `rayon`, or `proptest`,
//! so this module provides self-contained equivalents (documented in
//! DESIGN.md §10).

pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

pub use pool::{par_for, par_map};
pub use rng::Rng;

/// Round `x` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Next power of two >= x (x >= 1).
#[inline]
pub fn next_pow2(x: usize) -> usize {
    x.next_power_of_two()
}

/// Integer log2 of a power of two.
#[inline]
pub fn ilog2(x: usize) -> u32 {
    debug_assert!(x.is_power_of_two());
    x.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 4), 0);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(5, 4), 8);
        assert_eq!(round_up(13, 8), 16);
    }

    #[test]
    fn next_pow2_basic() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(4), 4);
        assert_eq!(next_pow2(129), 256);
    }

    #[test]
    fn ilog2_basic() {
        assert_eq!(ilog2(1), 0);
        assert_eq!(ilog2(8), 3);
        assert_eq!(ilog2(1024), 10);
    }
}
