//! Minimal property-based testing harness.
//!
//! `proptest` cannot be resolved offline in this container, so coordinator
//! invariants are checked with this seeded random-case runner instead
//! (DESIGN.md §8). No shrinking — failures print the case seed so they can
//! be replayed deterministically.

use super::rng::Rng;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop` for `cfg.cases` random cases. `gen` builds a case from an RNG;
/// `prop` returns `Err(msg)` on violation.
pub fn check<T: std::fmt::Debug, G, P>(cfg: &PropConfig, gen: G, prop: P)
where
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (replay seed {case_seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(
            &PropConfig { cases: 32, seed: 1 },
            |r| r.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(
            &PropConfig { cases: 64, seed: 2 },
            |r| r.below(10),
            |&x| if x < 5 { Ok(()) } else { Err("too big".into()) },
        );
    }
}
