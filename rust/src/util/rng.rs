//! Deterministic PRNG (PCG-XSH-RR 64/32) with normal-variate support.
//!
//! Self-contained replacement for the `rand` crate (unavailable offline).
//! All experiments in the repo take explicit seeds so every figure and test
//! is reproducible bit-for-bit.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second output of the Box-Muller transform.
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (seed << 1) | 1,
            spare_normal: None,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed ^ 0x9E3779B97F4A7C15);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-thread / per-rank use).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is fine for our
        // non-cryptographic workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Sparse rejection sampling.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let j = self.below(n);
                if seen.insert(j) {
                    out.push(j);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(13);
        let mut hit = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            hit[k] = true;
        }
        assert!(hit.iter().all(|&h| h));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for &(n, k) in &[(10, 10), (100, 7), (50, 25), (1000, 3)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
