//! Minimal JSON tree: writer + recursive-descent parser.
//!
//! The repo vendors no serde, so the benchmark trajectory files
//! (`BENCH_*.json`) and [`crate::metrics::run_trace::RunReport`] serialize
//! through this hand-rolled value type. The writer emits numbers with
//! Rust's shortest-round-trip `Display` and the parser reads them back
//! with `str::parse`, so a parse → re-serialize cycle is byte-stable —
//! the property the bench schema tests pin.

use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All JSON numbers parse to f64; integral values re-serialize without
    /// a fractional part (`Display` for f64 prints `3` for 3.0... it does
    /// not — see [`write_num`], which special-cases integral values).
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs (order is preserved so the
    /// serializer is deterministic).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Serialize without whitespace (deterministic, byte-stable).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let bytes = src.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(JsonError { pos, msg: "trailing characters after document" });
        }
        Ok(v)
    }
}

/// Integral f64s print without the `.0` Rust's `Display` would keep off
/// anyway — but NaN/∞ have no JSON form and serialize as null.
fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.007_199_254_740_992e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure: byte offset + static description.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: &'static str,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8, msg: &'static str) -> Result<(), JsonError> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(JsonError { pos: *pos, msg })
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(JsonError { pos: *pos, msg: "unexpected end of input" }),
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, b':', "expected ':' after object key")?;
                let val = parse_value(b, pos)?;
                fields.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(JsonError { pos: *pos, msg: "expected ',' or '}'" }),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut xs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(xs));
                    }
                    _ => return Err(JsonError { pos: *pos, msg: "expected ',' or ']'" }),
                }
            }
        }
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &'static str, v: Json) -> Result<Json, JsonError> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(JsonError { pos: *pos, msg: "invalid literal" })
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])
        .map_err(|_| JsonError { pos: start, msg: "invalid number" })?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| JsonError { pos: start, msg: "invalid number" })
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    expect(b, pos, b'"', "expected string")?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(JsonError { pos: *pos, msg: "unterminated string" }),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .and_then(|h| u32::from_str_radix(h, 16).ok())
                            .ok_or(JsonError { pos: *pos, msg: "invalid \\u escape" })?;
                        // Surrogate pairs are not needed for our schema;
                        // lone surrogates map to the replacement char.
                        out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(JsonError { pos: *pos, msg: "invalid escape" }),
                }
                *pos += 1;
            }
            Some(_) => {
                // Copy one UTF-8 scalar (multi-byte sequences pass through).
                let s = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| JsonError { pos: *pos, msg: "invalid utf-8" })?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_byte_stable() {
        let src = r#"{"a":1,"b":[1.5,"x",true,null],"c":{"d":-2}}"#;
        let v = Json::parse(src).unwrap();
        let once = v.to_string_compact();
        let twice = Json::parse(&once).unwrap().to_string_compact();
        assert_eq!(once, twice);
        assert_eq!(once, src);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n":42,"s":"hi","xs":[1,2]}"#).unwrap();
        assert_eq!(v.get("n").and_then(Json::as_usize), Some(42));
        assert_eq!(v.get("n").and_then(Json::as_u64), Some(42));
        assert_eq!(v.get("s").and_then(Json::as_str), Some("hi"));
        assert_eq!(v.get("xs").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn large_u64_counters_survive() {
        // FLOP counters are u64 but travel as f64: exact up to 2^53.
        let v = Json::parse("9007199254740991").unwrap();
        assert_eq!(v.as_u64(), Some((1u64 << 53) - 1));
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        let s = v.to_string_compact();
        assert_eq!(s, r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }
}
