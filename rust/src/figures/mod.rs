//! Figure-regeneration harness: one function per figure of the paper's
//! evaluation section (Figures 12-23). Each returns a text report whose
//! rows/series mirror what the paper plots; `h2ulv figures --out DIR` also
//! writes CSV files. Scaled-down problem sizes are used (this is a CPU
//! container, not 512 V100s) — the *shape* of each result is the
//! reproduction target (DESIGN.md §7).

use crate::baselines::blr::{BlrConfig, BlrMatrix};
use crate::batch::native::NativeBackend;
use crate::construct::H2Config;
use crate::dist::{dist_solve_driver, CommModel, NCCL_LIKE};
use crate::geometry::{molecule, Geometry};
use crate::h2::H2Matrix;
use crate::kernels::KernelFn;
use crate::linalg::norms::rel_err_vec;
use crate::metrics::{flops, timer::timed};
use crate::solver::{BackendSpec, FactorStorage, H2SolverBuilder};
use crate::tree::{leaf_near_count, ClusterTree};
use crate::ulv::{factorize, SubstMode};
use crate::util::Rng;

/// Problem-size scale for the harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Seconds-long runs (used by `cargo bench`).
    Quick,
    /// Minutes-long runs (used by `h2ulv figures`).
    Full,
}

fn pjrt_backend() -> Option<crate::runtime::PjrtBackend> {
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        crate::runtime::PjrtBackend::new(dir).ok()
    } else {
        None
    }
}

/// Standard solver configuration for the timing figures (self-similar
/// shapes: leaf = 2 * rank keeps the PJRT artifacts applicable everywhere).
fn timing_cfg() -> H2Config {
    H2Config { leaf_size: 64, max_rank: 32, far_samples: 128, near_samples: 96, ..Default::default() }
}

/// Figure 12 — profiler view: batched-kernel timeline and occupancy.
pub fn fig12(scale: Scale) -> String {
    let n = match scale {
        Scale::Quick => 2048,
        Scale::Full => 8192,
    };
    let g = Geometry::sphere_surface(n, 12);
    let h2 = H2Matrix::construct(&g, &KernelFn::laplace(), &timing_cfg());
    let mut out = format!("# Figure 12 analog: batched launch trace, N={n}\n");
    let tr = crate::metrics::RunTrace::new();
    // Prefer the PJRT (GPU-analog) backend; fall back to native tracing.
    if let Some(be) = pjrt_backend() {
        let be = be.with_trace(tr.clone());
        let _ = factorize(&h2, &be);
        out.push_str(&tr.render());
        out.push_str(&format!(
            "\nmean batch size (occupancy proxy): {:.1}\nlaunches: {}\n",
            tr.mean_batch(),
            tr.spans().len()
        ));
    } else {
        let be = NativeBackend::with_trace(tr.clone());
        let _ = factorize(&h2, &be);
        out.push_str(&tr.render());
        out.push_str(&format!("\nmean batch size: {:.1}\n", tr.mean_batch()));
    }
    out.push_str(
        "\npaper: 4x A100, N=262144 — high concurrency, batched POTRF/TRSM/GEMM per level.\n",
    );
    out
}

/// Figures 13 + 14 + 15 — factorization/substitution time vs N (O(N)),
/// FLOP rate, and FLOP count (between O(N) and O(N log N)).
///
/// The PJRT column reuses the native session via
/// [`crate::solver::H2Solver::rebind_backend`]: the H² matrix is built
/// once and the recorded plan is replayed on the second backend, so the
/// comparison isolates execution cost. Schedule statistics (launch counts
/// per level, padding waste) come straight from the plan IR.
pub fn fig13_14_15(scale: Scale) -> String {
    let sizes: Vec<usize> = match scale {
        Scale::Quick => vec![1024, 2048, 4096],
        Scale::Full => vec![1024, 2048, 4096, 8192, 16384, 32768],
    };
    let mut out = String::from(
        "# Figures 13/14/15: N, factor_native_s, subst_native_s, factor_pjrt_s, subst_pjrt_s, factor_gflop, gflops_native, launches, pad_waste, resid\n",
    );
    let mut schedule_note = String::new();
    for &n in &sizes {
        let g = Geometry::sphere_surface(n, 13);
        let mut solver = H2SolverBuilder::new(g, KernelFn::laplace())
            .config(timing_cfg())
            .residual_samples(64)
            .build()
            .expect("figure problem is well-formed");
        let t_factor = solver.stats().factor_time;
        let factor_flops = solver.stats().factor_flops;
        let launches = solver.stats().schedule.factor_launches();
        let pad_waste = solver.stats().schedule.factor_padding_waste();
        let mut rng = Rng::new(7);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let rep = solver.solve(&b).expect("rhs length matches");
        // PJRT column: replay the same plan on the rebound backend (no
        // second H² construction); NaN when artifacts are missing.
        let (t_factor_p, t_subst_p) = match solver.rebind_backend(BackendSpec::pjrt()) {
            Ok(stats) => {
                let t_f = stats.factor_time;
                let rp = solver
                    .solve_opts(&b, &crate::solver::SolveOptions::no_residual())
                    .expect("rhs length matches");
                (t_f, rp.subst_time)
            }
            Err(_) => (f64::NAN, f64::NAN),
        };
        out.push_str(&format!(
            "{n}, {t_factor:.4}, {:.4}, {t_factor_p:.4}, {t_subst_p:.4}, {:.3}, {:.3}, {launches}, {:.1}%, {:.2e}\n",
            rep.subst_time,
            factor_flops as f64 / 1e9,
            factor_flops as f64 / t_factor / 1e9,
            100.0 * pad_waste,
            rep.residual.unwrap_or(f64::NAN),
        ));
        if n == *sizes.last().unwrap() {
            schedule_note = format!(
                "\nschedule (from the plan IR, N={n}):\n{}",
                solver.plan().render_schedule()
            );
        }
    }
    out.push_str(&schedule_note);
    out.push_str("\npaper fig13: O(N) slope; fig14: 2.42 TF/s CPU, 12.18 TF/s GPU peak;\n");
    out.push_str("fig15: FLOP count between O(N) and O(N log2 N) until neighbor counts saturate.\n");
    out
}

/// Figure 16 — number of neighbor (dense) interactions vs leaf-box count,
/// saturating to the O(N) bound.
pub fn fig16(scale: Scale) -> String {
    let max_pow = match scale {
        Scale::Quick => 15,
        Scale::Full => 18,
    };
    let mut out = String::from("# Figure 16: N, leaf_boxes, neighbor_pairs, pairs_per_box\n");
    for pow in 10..=max_pow {
        let n = 1usize << pow;
        let g = Geometry::sphere_surface(n, 16);
        let t = ClusterTree::build(&g, 64);
        let count = leaf_near_count(&t, 1.0);
        let boxes = t.width(t.depth);
        out.push_str(&format!(
            "{n}, {boxes}, {count}, {:.2}\n",
            count as f64 / boxes as f64
        ));
    }
    out.push_str("\npaper: pairs/box grows then saturates at the theoretical bound -> O(N) total.\n");
    out
}

/// Figure 17 — FLOP split between pre-factorization (factorization-basis
/// construction) and the ULV factorization, vs admissibility eta.
pub fn fig17(scale: Scale) -> String {
    let n = match scale {
        Scale::Quick => 2048,
        Scale::Full => 8192,
    };
    let mut out =
        String::from("# Figure 17: eta, prefactor_gflop, factor_gflop, prefactor_share\n");
    for eta in [0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0] {
        let g = Geometry::sphere_surface(n, 17);
        let cfg = H2Config { eta, ..timing_cfg() };
        // One scope per data point: construction attributes its basis work
        // to Prefactor internally (h2::construct uses with_phase).
        let scope = flops::FlopScope::new();
        let h2 = flops::scoped(&scope, flops::Phase::Construct, || {
            H2Matrix::construct(&g, &KernelFn::laplace(), &cfg)
        });
        let _fac = flops::scoped(&scope, flops::Phase::Factor, || {
            factorize(&h2, &NativeBackend::new())
        });
        let c = scope.snapshot();
        let pre = c.prefactor;
        let fac = c.factor;
        let share = pre as f64 / (pre + fac).max(1) as f64;
        out.push_str(&format!(
            "{eta:.1}, {:.3}, {:.3}, {:.1}%\n",
            pre as f64 / 1e9,
            fac as f64 / 1e9,
            100.0 * share
        ));
    }
    out.push_str("\npaper: pre-factorization stays <= ~46% of total and scales linearly with eta.\n");
    out
}

/// Figures 18 + 19 — rank vs solution accuracy and accuracy vs
/// time-to-solution for H² (eta=1) against HSS (eta=0).
pub fn fig18_19(scale: Scale) -> String {
    let (n, leaf, ranks): (usize, usize, Vec<usize>) = match scale {
        Scale::Quick => (1024, 128, vec![16, 32, 64]),
        Scale::Full => (2048, 256, vec![8, 16, 24, 32, 48, 64, 96, 128]),
    };
    let g = Geometry::sphere_surface(n, 18);
    let kern = KernelFn::laplace();
    let mut rng = Rng::new(19);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    // Dense oracle (paper: fixed-rank truncation, sampling disabled).
    let dense = crate::baselines::dense::DenseSolver::factorize(&g.points, &kern).unwrap();
    let x_dense = dense.solve(&b);
    let mut out = String::from(
        "# Figures 18/19: rank, err_h2, err_hss, time_h2_s, time_hss_s  (N=",
    );
    out.push_str(&format!("{n}, leaf={leaf}, sampling off)\n"));
    for &rank in &ranks {
        let mut row = format!("{rank}");
        for eta in [1.0, 0.0] {
            let cfg = H2Config {
                leaf_size: leaf,
                max_rank: rank,
                far_samples: 0,
                near_samples: 0,
                eta,
                ..Default::default()
            };
            let solver = H2SolverBuilder::new(g.clone(), kern.clone())
                .config(cfg)
                .residual_samples(0)
                .build()
                .expect("figure problem is well-formed");
            let rep = solver.solve(&b).expect("rhs length matches");
            let err = rel_err_vec(&rep.x, &x_dense);
            let t = solver.stats().construct_time + solver.stats().factor_time + rep.subst_time;
            row.push_str(&format!(", {err:.3e}, {t:.3}"));
        }
        out.push_str(&row);
        out.push('\n');
    }
    out.push_str("\ncolumns: rank, err_h2, time_h2, err_hss, time_hss\n");
    out.push_str("paper: HSS needs rank>400 to match H2@50; here the gap is a consistent factor\n");
    out.push_str("(2-4x at equal rank, growing with rank) — see EXPERIMENTS.md for the deviation note.\n");
    out
}

/// Figure 20 — strong scaling vs the BLR (LORAPO-analog) baseline.
pub fn fig20(scale: Scale) -> String {
    let (n, ps): (usize, Vec<usize>) = match scale {
        Scale::Quick => (4096, vec![1, 2, 4]),
        Scale::Full => (16384, vec![1, 2, 4, 8, 16, 32]),
    };
    let base = molecule::hemoglobin_like(0.15, 20);
    let copies = n / base.len() + 1;
    let g = base.duplicate_lattice(copies, 6.0).truncated(n);
    let kern = KernelFn::yukawa();
    let mut rng = Rng::new(21);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut out = format!("# Figure 20 (strong scaling): N={n}, P, h2_factor_s(modeled), h2_subst_s\n");
    // BLR comparator geometry (carved before `g` moves into the builder):
    // measured at a feasible size, extrapolated O(N²) below.
    let blr_n = match scale {
        Scale::Quick => 2048,
        Scale::Full => 4096,
    };
    let g_blr = g.truncated(blr_n);
    // One DeviceOnly session serves every rank count: the factor stays
    // resident with no host mirror at all (the distributed model reads
    // every block shape from FactorMeta), and each call leases a pooled
    // workspace — times are modeled with the NCCL-like constants.
    let solver = H2SolverBuilder::new(g, kern.clone())
        .config(timing_cfg())
        .factor_storage(FactorStorage::DeviceOnly)
        .residual_samples(0)
        .build()
        .expect("figure problem is well-formed");
    debug_assert!(solver.factor().is_none(), "device-only session must not mirror");
    for &p in &ps {
        let report = solver.solve_dist(&b, p).expect("rhs length matches");
        out.push_str(&format!("{p}, {:.4}, {:.4}\n", report.factor_time, report.subst_time));
    }
    // (LORAPO could not reach the paper's sizes either — fig 20 shows it
    // only at small N.)
    let tree = ClusterTree::build(&g_blr, 128);
    let (mut blr, t_build) = timed(|| BlrMatrix::build(&tree.points, &kern, &BlrConfig::default()));
    let (_, t_blr) = timed(|| blr.factorize());
    let scale_up = (n as f64 / blr_n as f64).powi(2);
    out.push_str(&format!(
        "\nBLR baseline: measured factorization {t_blr:.3}s at N={blr_n} (build {t_build:.2}s);\n\
         O(N^2)-extrapolated to N={n}: {:.2}s on 1 rank (paper: 13,300x gap at 128 ranks).\n",
        t_blr * scale_up
    ));
    out
}

/// Figures 21 + 22 + 23 — weak scaling of factorization and substitution,
/// plus the compute-vs-communication breakdown.
pub fn fig21_22_23(scale: Scale) -> String {
    let (base_n, ps): (usize, Vec<usize>) = match scale {
        Scale::Quick => (2048, vec![1, 2, 4]),
        Scale::Full => (4096, vec![1, 2, 4, 8, 16]),
    };
    let kern = KernelFn::yukawa();
    let model: CommModel = NCCL_LIKE;
    let mut out = String::from(
        "# Figures 21/22/23 (weak scaling): P, N, factor_s, subst_s, factor_comm_s, subst_comm_s, comm_share_subst\n",
    );
    for &p in &ps {
        let n = base_n * p;
        let base = molecule::hemoglobin_like(0.12, 22);
        let copies = n / base.len() + 1;
        let g = base.duplicate_lattice(copies, 6.0).truncated(n);
        let h2 = H2Matrix::construct(&g, &kern, &timing_cfg());
        let mut rng = Rng::new(23);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let bt = h2.tree.permute_vec(&b);
        let report = dist_solve_driver(&h2, p, &bt, SubstMode::Parallel);
        let f_comm = model.cost(report.factor_ops, report.factor_bytes);
        let s_comm = model.cost(report.subst_ops, report.subst_bytes);
        let tf = report.factor_time(&model);
        let ts = report.subst_time(&model);
        out.push_str(&format!(
            "{p}, {n}, {tf:.4}, {ts:.4}, {f_comm:.5}, {s_comm:.5}, {:.1}%\n",
            100.0 * s_comm / ts.max(1e-12)
        ));
    }
    out.push_str("\npaper fig21: factorization ~O(log2 P) (redundant top levels);\n");
    out.push_str("fig22: substitution O(P) neighbor-comm regime then O(log2 P) at scale;\n");
    out.push_str("fig23: substitution becomes communication-dominated as P grows.\n");
    out
}

/// Run every figure and (optionally) write reports into `out_dir`.
pub fn run_all(scale: Scale, out_dir: Option<&std::path::Path>) -> String {
    let figures: Vec<(&str, String)> = vec![
        ("fig12", fig12(scale)),
        ("fig13_14_15", fig13_14_15(scale)),
        ("fig16", fig16(scale)),
        ("fig17", fig17(scale)),
        ("fig18_19", fig18_19(scale)),
        ("fig20", fig20(scale)),
        ("fig21_22_23", fig21_22_23(scale)),
    ];
    let mut all = String::new();
    for (name, report) in &figures {
        all.push_str(&format!("\n================ {name} ================\n"));
        all.push_str(report);
        if let Some(dir) = out_dir {
            std::fs::create_dir_all(dir).ok();
            std::fs::write(dir.join(format!("{name}.txt")), report).ok();
        }
    }
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig16_report_has_rows() {
        let r = fig16(Scale::Quick);
        assert!(r.lines().count() >= 6);
        assert!(r.contains("neighbor_pairs"));
    }

    #[test]
    fn fig17_shares_are_bounded() {
        let r = fig17(Scale::Quick);
        // Parse prefactor shares and check they stay below ~60%
        for line in r.lines().skip(1) {
            if let Some(pct) = line.split(", ").nth(3) {
                if let Ok(v) = pct.trim_end_matches('%').parse::<f64>() {
                    assert!(v < 75.0, "prefactor share too large: {v}% ({line})");
                }
            }
        }
    }
}
