//! # h2ulv — inherently parallel H²-ULV factorization
//!
//! A complete reproduction of *"An inherently parallel H²-ULV factorization
//! for solving dense linear systems on GPUs"* (Qianxiang Ma & Rio Yokota,
//! IJHPCA 2024, DOI 10.1177/10943420241242021) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The library provides:
//! * a from-scratch dense linear-algebra substrate ([`linalg`]),
//! * geometry generators and cluster trees with strong admissibility
//!   ([`geometry`], [`tree`]),
//! * H²-matrix construction with the paper's *factorization basis*
//!   ([`construct`], [`h2`]),
//! * the inherently parallel ULV factorization and the novel parallel
//!   forward/backward substitution ([`ulv`]), driven by a recorded,
//!   replayable execution-plan IR ([`plan`]),
//! * a batched-execution engine behind the arena-native device-resident
//!   launch API ([`batch::device::Device`]), with a native thread-pool
//!   backend and an XLA/PJRT backend that runs AOT-compiled JAX/Pallas
//!   artifacts ([`batch`], [`runtime`]),
//! * a distributed-memory runtime: real SPMD thread-rank execution over
//!   rank-sharded arenas with plan-level `Exchange` collectives
//!   ([`dist::exec`]), plus the NCCL-like α-β communication model it is
//!   validated against ([`dist`]),
//! * baselines (dense Cholesky, BLR tile-Cholesky ≈ LORAPO) ([`baselines`]),
//! * FLOP/time/communication metrics and the figure-regeneration harness
//!   ([`metrics`], [`figures`]),
//! * structured end-to-end run tracing and the benchmark trajectory
//!   harness behind `BENCH_*.json` ([`metrics::run_trace`], [`bench`]),
//! * the end-to-end session facade — builder-configured, `Result`-based,
//!   backend-pluggable ([`solver`]). **Start here**: the layered modules
//!   stay public for benchmarks, but [`solver::H2SolverBuilder`] /
//!   [`solver::H2Solver`] are the intended entry point,
//! * a multi-tenant solve service over the facade — line-oriented JSON
//!   protocol, plan-keyed session cache with LRU byte-budget eviction,
//!   admission control, and request micro-batching ([`serve`]).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod baselines;
pub mod batch;
pub mod bench;
pub mod construct;
pub mod dist;
pub mod figures;
pub mod geometry;
pub mod h2;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod plan;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod tree;
pub mod ulv;
pub mod util;

pub mod cli;

/// Convenience re-exports for downstream users: the solver facade plus the
/// types needed to describe a problem.
pub mod prelude {
    pub use crate::construct::H2Config;
    pub use crate::geometry::Geometry;
    pub use crate::kernels::KernelFn;
    pub use crate::linalg::Matrix;
    pub use crate::solver::{
        BackendSpec, BuildStats, DistSolveReport, FactorBlock, FactorStorage, H2Error, H2Solver,
        H2SolverBuilder, SolveOptions, SolveReport,
    };
    pub use crate::ulv::SubstMode;
}
