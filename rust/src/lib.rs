//! # h2ulv — inherently parallel H²-ULV factorization
//!
//! A complete reproduction of *"An inherently parallel H²-ULV factorization
//! for solving dense linear systems on GPUs"* (Qianxiang Ma & Rio Yokota,
//! IJHPCA 2024, DOI 10.1177/10943420241242021) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The library provides:
//! * a from-scratch dense linear-algebra substrate ([`linalg`]),
//! * geometry generators and cluster trees with strong admissibility
//!   ([`geometry`], [`tree`]),
//! * H²-matrix construction with the paper's *factorization basis*
//!   ([`construct`], [`h2`]),
//! * the inherently parallel ULV factorization and the novel parallel
//!   forward/backward substitution ([`ulv`]),
//! * a batched-execution engine with a native thread-pool backend and an
//!   XLA/PJRT backend that runs AOT-compiled JAX/Pallas artifacts
//!   ([`batch`], [`runtime`]),
//! * a simulated distributed-memory runtime with NCCL-like collectives
//!   ([`dist`]),
//! * baselines (dense Cholesky, BLR tile-Cholesky ≈ LORAPO) ([`baselines`]),
//! * FLOP/time/communication metrics and the figure-regeneration harness
//!   ([`metrics`], [`figures`]).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod baselines;
pub mod batch;
pub mod construct;
pub mod dist;
pub mod figures;
pub mod geometry;
pub mod h2;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod runtime;
pub mod tree;
pub mod ulv;
pub mod util;

pub mod cli;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::linalg::Matrix;
}
