//! Binary cluster tree and H² interaction lists.
//!
//! The dense matrix is block-partitioned by recursively bisecting the point
//! cloud along the longest bounding-box axis (median split), producing a
//! perfect binary tree whose leaves hold at most `leaf_size` points. Points
//! are *reordered* so every node owns a contiguous index range — this is the
//! space-filling-style ordering that also gives the 1-D process
//! distribution its data locality (paper §5).
//!
//! The admissibility condition follows the paper (§6.2): a pair of distinct
//! boxes is **admissible** (compressed low-rank) when
//! `dist(center_i, center_j) >= eta * max(radius_i, radius_j)`;
//! `eta = 0` reproduces HSS/weak admissibility (every off-diagonal pair is
//! low-rank), larger `eta` keeps more near (dense) blocks, matching the
//! paper's "admissibility condition number ... from 0.0 (HSS admissibility)
//! to 3.0".

pub mod lists;

use crate::geometry::{Aabb, Geometry, Point3};

pub use lists::{interaction_lists, leaf_near_count, LevelLists};

/// A node (box) of the cluster tree.
#[derive(Clone, Debug)]
pub struct Node {
    /// Tree level (0 = root).
    pub level: usize,
    /// Index within the level (`0..2^level`).
    pub index: usize,
    /// First owned point (in tree ordering).
    pub begin: usize,
    /// One past the last owned point.
    pub end: usize,
    /// Bounding box of the owned points.
    pub bbox: Aabb,
}

impl Node {
    /// Number of points owned by this node.
    pub fn len(&self) -> usize {
        self.end - self.begin
    }

    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }
}

/// Perfect binary cluster tree with reordered points.
#[derive(Clone, Debug)]
pub struct ClusterTree {
    /// Leaf level index L (the tree has levels `0..=L`).
    pub depth: usize,
    /// Nodes in level order: node `(l, i)` at `(1 << l) - 1 + i`.
    pub nodes: Vec<Node>,
    /// Points in tree order.
    pub points: Vec<Point3>,
    /// `perm[p]` = original index of tree-ordered point `p`.
    pub perm: Vec<usize>,
}

/// Flat id of node `(level, index)`.
#[inline]
pub fn node_id(level: usize, index: usize) -> usize {
    (1usize << level) - 1 + index
}

impl ClusterTree {
    /// Build a tree over `geometry` with at most `leaf_size` points per leaf.
    pub fn build(geometry: &Geometry, leaf_size: usize) -> ClusterTree {
        assert!(leaf_size >= 1);
        let n = geometry.len();
        assert!(n >= 1, "empty geometry");
        // Depth so that each leaf holds <= leaf_size points.
        let mut depth = 0usize;
        while n.div_ceil(1 << depth) > leaf_size {
            depth += 1;
        }
        let mut order: Vec<usize> = (0..n).collect();
        let mut nodes: Vec<Node> = Vec::with_capacity((1 << (depth + 1)) - 1);
        // Build level by level; each node splits its range at the median of
        // the longest bbox axis.
        struct Range {
            begin: usize,
            end: usize,
        }
        let mut current = vec![Range { begin: 0, end: n }];
        for level in 0..=depth {
            let mut next = Vec::with_capacity(current.len() * 2);
            for (index, r) in current.iter().enumerate() {
                let slice = &order[r.begin..r.end];
                let bbox = Aabb::of(&slice.iter().map(|&p| geometry.points[p]).collect::<Vec<_>>());
                nodes.push(Node { level, index, begin: r.begin, end: r.end, bbox });
                if level < depth {
                    let axis = bbox.longest_axis();
                    let mid = r.begin + (r.end - r.begin) / 2;
                    let sub = &mut order[r.begin..r.end];
                    let k = mid - r.begin;
                    if k > 0 && k < sub.len() {
                        sub.select_nth_unstable_by(k, |&a, &b| {
                            geometry.points[a][axis]
                                .partial_cmp(&geometry.points[b][axis])
                                .unwrap()
                        });
                    }
                    next.push(Range { begin: r.begin, end: mid });
                    next.push(Range { begin: mid, end: r.end });
                }
            }
            current = next;
        }
        let points: Vec<Point3> = order.iter().map(|&p| geometry.points[p]).collect();
        ClusterTree { depth, nodes, points, perm: order }
    }

    /// Node `(level, index)`.
    #[inline]
    pub fn node(&self, level: usize, index: usize) -> &Node {
        &self.nodes[node_id(level, index)]
    }

    /// Number of nodes at `level`.
    #[inline]
    pub fn width(&self, level: usize) -> usize {
        1 << level
    }

    /// Leaf nodes slice.
    pub fn leaves(&self) -> &[Node] {
        &self.nodes[node_id(self.depth, 0)..]
    }

    /// Apply the tree permutation to a vector in original ordering.
    pub fn permute_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.perm.len());
        self.perm.iter().map(|&p| x[p]).collect()
    }

    /// Inverse of [`permute_vec`]: tree ordering back to original ordering.
    pub fn unpermute_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.perm.len());
        let mut out = vec![0.0; x.len()];
        for (t, &orig) in self.perm.iter().enumerate() {
            out[orig] = x[t];
        }
        out
    }

    /// The paper's admissibility test between two nodes at the same level.
    #[inline]
    pub fn admissible(&self, a: &Node, b: &Node, eta: f64) -> bool {
        if a.level == b.level && a.index == b.index {
            return false;
        }
        let d = crate::geometry::dist(&a.bbox.center(), &b.bbox.center());
        let r = a.bbox.radius().max(b.bbox.radius());
        d >= eta * r && d > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Geometry;

    #[test]
    fn tree_structure_invariants() {
        let g = Geometry::uniform_cube(1000, 21);
        let t = ClusterTree::build(&g, 64);
        // leaf sizes
        for leaf in t.leaves() {
            assert!(leaf.len() <= 64);
            assert!(leaf.len() >= 32, "median splits keep leaves balanced");
        }
        // every level partitions [0, n)
        for l in 0..=t.depth {
            let mut covered = 0;
            for i in 0..t.width(l) {
                let node = t.node(l, i);
                assert_eq!(node.begin, covered);
                covered = node.end;
            }
            assert_eq!(covered, 1000);
        }
        // children partition parent
        for l in 0..t.depth {
            for i in 0..t.width(l) {
                let p = t.node(l, i);
                let c0 = t.node(l + 1, 2 * i);
                let c1 = t.node(l + 1, 2 * i + 1);
                assert_eq!(p.begin, c0.begin);
                assert_eq!(c0.end, c1.begin);
                assert_eq!(c1.end, p.end);
            }
        }
    }

    #[test]
    fn perm_roundtrip() {
        let g = Geometry::sphere_surface(257, 23);
        let t = ClusterTree::build(&g, 32);
        let x: Vec<f64> = (0..257).map(|i| i as f64).collect();
        let y = t.permute_vec(&x);
        let z = t.unpermute_vec(&y);
        assert_eq!(x, z);
        // permuted points match
        for (tp, &orig) in t.perm.iter().enumerate() {
            assert_eq!(t.points[tp], g.points[orig]);
        }
    }

    #[test]
    fn single_leaf_tree() {
        let g = Geometry::uniform_cube(10, 25);
        let t = ClusterTree::build(&g, 16);
        assert_eq!(t.depth, 0);
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.node(0, 0).len(), 10);
    }

    #[test]
    fn admissibility_eta_zero_is_weak() {
        let g = Geometry::uniform_cube(256, 27);
        let t = ClusterTree::build(&g, 32);
        let l = t.depth;
        for i in 0..t.width(l) {
            for j in 0..t.width(l) {
                let adm = t.admissible(t.node(l, i), t.node(l, j), 0.0);
                assert_eq!(adm, i != j, "eta=0 must make all off-diagonal admissible");
            }
        }
    }

    #[test]
    fn admissibility_monotone_in_eta() {
        let g = Geometry::sphere_surface(512, 29);
        let t = ClusterTree::build(&g, 32);
        let l = t.depth;
        let count = |eta: f64| -> usize {
            let mut c = 0;
            for i in 0..t.width(l) {
                for j in 0..t.width(l) {
                    if t.admissible(t.node(l, i), t.node(l, j), eta) {
                        c += 1;
                    }
                }
            }
            c
        };
        let c0 = count(0.0);
        let c1 = count(1.0);
        let c2 = count(2.0);
        assert!(c0 >= c1 && c1 >= c2, "admissible pairs shrink as eta grows");
        assert!(c2 > 0);
    }
}
