//! Per-level near/far interaction lists (the H² block structure).
//!
//! Computed by a level-by-level dual traversal: a pair at level `l` exists
//! only if its parent pair was *near* at level `l-1`; it becomes a **far**
//! (coupling) block if admissible, otherwise a **near** block. At the leaf
//! level near blocks are stored dense; at interior levels near blocks are
//! the merged `A^SS` content the ULV factorization keeps working on.

use super::ClusterTree;

/// Interaction lists for one tree level.
#[derive(Clone, Debug, Default)]
pub struct LevelLists {
    /// Non-admissible pairs `(i, j)` (within-level indices), including the
    /// diagonal `(i, i)`. Both `(i, j)` and `(j, i)` appear.
    pub near: Vec<(usize, usize)>,
    /// Admissible pairs whose parent pair is near.
    pub far: Vec<(usize, usize)>,
}

impl LevelLists {
    /// Near pairs of row `i` (linear scan; lists are level-local and small).
    pub fn near_of_row(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.near.iter().filter(move |&&(a, _)| a == i).map(|&(_, b)| b)
    }

    /// Far pairs of row `i`.
    pub fn far_of_row(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.far.iter().filter(move |&&(a, _)| a == i).map(|&(_, b)| b)
    }
}

/// Build the near/far lists for every level of `tree` under admissibility
/// parameter `eta` (paper's "admissibility condition number").
pub fn interaction_lists(tree: &ClusterTree, eta: f64) -> Vec<LevelLists> {
    let mut lists: Vec<LevelLists> = vec![LevelLists::default(); tree.depth + 1];
    // Root level: the single (0,0) pair is near.
    lists[0].near.push((0, 0));
    for l in 1..=tree.depth {
        // Split parent near pairs into this level's near/far.
        let parent_near = lists[l - 1].near.clone();
        for &(pi, pj) in &parent_near {
            for ci in [2 * pi, 2 * pi + 1] {
                for cj in [2 * pj, 2 * pj + 1] {
                    let a = tree.node(l, ci);
                    let b = tree.node(l, cj);
                    if tree.admissible(a, b, eta) {
                        lists[l].far.push((ci, cj));
                    } else {
                        lists[l].near.push((ci, cj));
                    }
                }
            }
        }
        lists[l].near.sort_unstable();
        lists[l].far.sort_unstable();
    }
    lists
}

/// Number of near (dense) blocks at the leaf level — the paper's `N_NZB`
/// "number of neighboring interactions" (Figure 16).
pub fn leaf_near_count(tree: &ClusterTree, eta: f64) -> usize {
    interaction_lists(tree, eta)[tree.depth].near.len()
}

/// Structural invariant checks used by tests and the property harness:
/// lists are symmetric, disjoint, complete w.r.t. the parent near pairs,
/// and every diagonal pair is near.
pub fn check_lists(tree: &ClusterTree, lists: &[LevelLists]) -> Result<(), String> {
    if lists.len() != tree.depth + 1 {
        return Err("wrong number of levels".into());
    }
    for (l, ll) in lists.iter().enumerate() {
        let near: std::collections::HashSet<_> = ll.near.iter().copied().collect();
        let far: std::collections::HashSet<_> = ll.far.iter().copied().collect();
        if near.len() != ll.near.len() || far.len() != ll.far.len() {
            return Err(format!("level {l}: duplicate pairs"));
        }
        // Symmetry.
        for &(i, j) in &ll.near {
            if !near.contains(&(j, i)) {
                return Err(format!("level {l}: near pair ({i},{j}) not symmetric"));
            }
        }
        for &(i, j) in &ll.far {
            if !far.contains(&(j, i)) {
                return Err(format!("level {l}: far pair ({i},{j}) not symmetric"));
            }
        }
        // Disjoint.
        if ll.near.iter().any(|p| far.contains(p)) {
            return Err(format!("level {l}: near/far overlap"));
        }
        // Diagonal near.
        for i in 0..tree.width(l) {
            if !near.contains(&(i, i)) {
                return Err(format!("level {l}: diagonal ({i},{i}) not near"));
            }
        }
        // Completeness: every pair present iff parent near.
        if l > 0 {
            let parent_near: std::collections::HashSet<_> =
                lists[l - 1].near.iter().copied().collect();
            for &(i, j) in ll.near.iter().chain(ll.far.iter()) {
                if !parent_near.contains(&(i / 2, j / 2)) {
                    return Err(format!("level {l}: pair ({i},{j}) has non-near parent"));
                }
            }
            for &(pi, pj) in &parent_near {
                for ci in [2 * pi, 2 * pi + 1] {
                    for cj in [2 * pj, 2 * pj + 1] {
                        if !near.contains(&(ci, cj)) && !far.contains(&(ci, cj)) {
                            return Err(format!(
                                "level {l}: child pair ({ci},{cj}) of near parent missing"
                            ));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Geometry;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn lists_invariants_sphere() {
        let g = Geometry::sphere_surface(1024, 31);
        let t = ClusterTree::build(&g, 64);
        for eta in [0.0, 0.7, 1.5, 3.0] {
            let lists = interaction_lists(&t, eta);
            check_lists(&t, &lists).unwrap();
        }
    }

    #[test]
    fn eta_zero_gives_hss_structure() {
        // With weak admissibility only the diagonal is near at every level.
        let g = Geometry::uniform_cube(512, 33);
        let t = ClusterTree::build(&g, 32);
        let lists = interaction_lists(&t, 0.0);
        for l in 1..=t.depth {
            assert_eq!(lists[l].near.len(), t.width(l), "level {l} near must be diagonal only");
            assert!(lists[l].near.iter().all(|&(i, j)| i == j));
        }
    }

    #[test]
    fn near_count_grows_with_eta() {
        let g = Geometry::sphere_surface(2048, 35);
        let t = ClusterTree::build(&g, 64);
        let c0 = leaf_near_count(&t, 0.5);
        let c1 = leaf_near_count(&t, 1.5);
        let c2 = leaf_near_count(&t, 3.0);
        assert!(c0 <= c1 && c1 <= c2);
        assert!(c2 > c0, "eta must change the structure");
    }

    #[test]
    fn prop_lists_invariants_random_geometry() {
        // Property harness: random clouds, random eta — invariants hold.
        check(
            &PropConfig { cases: 12, seed: 0xBEEF },
            |rng| {
                let n = 64 + rng.below(512);
                let seed = rng.next_u64();
                let eta = rng.range(0.0, 3.0);
                let leaf = 16 + rng.below(48);
                (n, seed, eta, leaf)
            },
            |&(n, seed, eta, leaf)| {
                let g = Geometry::uniform_cube(n, seed);
                let t = ClusterTree::build(&g, leaf);
                let lists = interaction_lists(&t, eta);
                check_lists(&t, &lists)
            },
        );
    }
}
