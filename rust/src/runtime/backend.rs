//! The PJRT batched backend.
//!
//! Implements the arena-native [`Device`] trait: launches arrive with
//! `BufferId` operands against the shared host-staging
//! [`HostArena`](crate::batch::device::HostArena), and each batched math
//! opcode ships a **first-class padded upload** to the AOT XLA executable:
//! the padded `[bucket, k, k]` buffer is written directly from the arena's
//! matrix references ([`crate::batch::pad::refs_to_buffer_f64`]), with
//! identity-diagonal fill for the factorization kernels — no per-op
//! clone/resize round trips. A real GPU PJRT arena would keep device
//! literals resident instead of host staging; the seam is the same.
//!
//! Shapes that exceed every compiled family (e.g. the dense root block)
//! fall back to the native kernels — mirroring how the paper handles the
//! final `cholesky(A_00)` outside the batched path.

use super::manifest::Manifest;
use crate::batch::device::{
    exec_host_launch, exec_host_solve_launch, host_arena, host_arena_ref, Device, DeviceArena,
    HostArena, HostKernels, Launch,
};
use crate::batch::native::NativeBackend;
use crate::batch::pad::{buffer_to_batch_f64, refs_to_buffer_f64, vecs_to_buffer_f64};
use crate::linalg::Matrix;
use crate::metrics::flops;
use crate::metrics::RunTrace;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Launch statistics (diagnostics + tests).
#[derive(Default)]
pub struct PjrtStats {
    /// Batched launches executed through PJRT.
    pub launches: AtomicU64,
    /// Calls that fell back to the native backend.
    pub fallbacks: AtomicU64,
}

/// Batched backend executing AOT XLA artifacts on the PJRT CPU client.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Compiled-executable cache keyed like the manifest index.
    cache: Mutex<HashMap<(String, usize, usize, usize), xla::PjRtLoadedExecutable>>,
    fallback: NativeBackend,
    pub stats: PjrtStats,
    pub trace: Option<RunTrace>,
}

// SAFETY: all PJRT interactions go through &self methods that funnel into
// `run`, which holds the compile-cache Mutex for the whole
// compile-and-execute sequence — so even concurrent `launch_solve` callers
// (the session's multi-threaded solve path) serialize their XLA work, and
// the PJRT CPU client itself is internally synchronized. The raw pointers
// inside the xla wrappers are never shared across threads concurrently by
// this type.
unsafe impl Sync for PjrtBackend {}
unsafe impl Send for PjrtBackend {}

impl PjrtBackend {
    /// Create a backend from an artifacts directory (with `manifest.json`).
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<PjrtBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtBackend {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            fallback: NativeBackend::new(),
            stats: PjrtStats::default(),
            trace: None,
        })
    }

    /// Record every batched launch into `trace` (fig 12 analog).
    pub fn with_trace(mut self, trace: RunTrace) -> Self {
        self.trace = Some(trace);
        self
    }

    fn trace<T>(
        &self,
        level: usize,
        kernel: &'static str,
        batch: usize,
        shape: (usize, usize),
        f: impl FnOnce() -> T,
    ) -> T {
        match &self.trace {
            Some(tr) => tr.record(level, kernel, batch, shape, f),
            None => f(),
        }
    }

    /// Execute `op` on row-major f64 buffers shaped by the artifact spec.
    /// Returns the first tuple element's flat data.
    fn run(
        &self,
        op: &str,
        bucket: usize,
        d: usize,
        k: usize,
        inputs: &[(Vec<f64>, [i64; 3])],
    ) -> anyhow::Result<Vec<f64>> {
        let key = (op.to_string(), bucket, d, k);
        let mut cache = self.cache.lock().unwrap();
        if !cache.contains_key(&key) {
            let path = self
                .manifest
                .index
                .get(&key)
                .ok_or_else(|| anyhow::anyhow!("no artifact for {key:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            cache.insert(key.clone(), exe);
        }
        let exe = cache.get(&key).unwrap();
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(buf, dims)| xla::Literal::vec1(buf).reshape(dims).map_err(anyhow::Error::from))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        self.stats.launches.fetch_add(1, Ordering::Relaxed);
        Ok(out.to_vec::<f64>()?)
    }

    /// Split work indices into bucket-sized chunks (largest bucket first).
    fn chunks(&self, n: usize) -> Vec<(usize, usize)> {
        // Returns (start, len) chunks with len <= max bucket.
        let maxb = self.manifest.max_bucket().max(1);
        let mut out = Vec::new();
        let mut at = 0;
        while at < n {
            let len = (n - at).min(maxb);
            out.push((at, len));
            at += len;
        }
        out
    }

    /// In-place batched Cholesky through the `potrf` artifacts.
    pub fn potrf(&self, level: usize, blocks: &mut [Matrix]) {
        if blocks.is_empty() {
            return;
        }
        let need = blocks.iter().map(|b| b.rows()).max().unwrap();
        let fam = match self.manifest.family_for(need * 2, need) {
            Some(f) => f,
            None => {
                self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
                return self.fallback.potrf(level, blocks);
            }
        };
        let (d, k) = fam;
        self.trace(level, "POTRF(pjrt)", blocks.len(), (need, need), || {
            for (start, len) in self.chunks(blocks.len()) {
                let bucket = self.manifest.bucket_for(len).unwrap();
                let chunk: Vec<&Matrix> = blocks[start..start + len].iter().collect();
                // Padded upload straight from the block refs: identity
                // diagonal so the padded Cholesky is valid (the paper's
                // AXPY-diagonal trick), identity padding slots likewise.
                let buf = refs_to_buffer_f64(&chunk, bucket, k, k, 1.0);
                for b in &chunk {
                    flops::add(flops::potrf_flops(b.rows()));
                }
                let shapes: Vec<(usize, usize)> =
                    chunk.iter().map(|b| (b.rows(), b.cols())).collect();
                let out = self
                    .run("potrf", bucket, d, k, &[(buf, [bucket as i64, k as i64, k as i64])])
                    .expect("potrf artifact execution failed");
                let mats = buffer_to_batch_f64(&out, k, k, &shapes);
                for (t, m) in mats.into_iter().enumerate() {
                    blocks[start + t] = m;
                }
            }
        });
    }

    /// Batched right-lower-transposed TRSM through the `trsm` artifacts.
    pub fn trsm_right_lt(&self, level: usize, l: &[&Matrix], b: &mut [Matrix]) {
        if b.is_empty() {
            return;
        }
        assert_eq!(l.len(), b.len());
        let need_l = l.iter().map(|m| m.rows()).max().unwrap();
        let need_rows = b.iter().map(|m| m.rows()).max().unwrap();
        let need = need_l.max(need_rows);
        let fam = match self.manifest.family_for(need * 2, need) {
            Some(f) => f,
            None => {
                self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
                return self.fallback.trsm_right_lt(level, l, b);
            }
        };
        let (d, k) = fam;
        self.trace(level, "TRSM(pjrt)", b.len(), (need_rows, need_l), || {
            for (start, len) in self.chunks(b.len()) {
                let bucket = self.manifest.bucket_for(len).unwrap();
                let brefs: Vec<&Matrix> = b[start..start + len].iter().collect();
                let lbuf = refs_to_buffer_f64(&l[start..start + len], bucket, k, k, 1.0);
                let bbuf = refs_to_buffer_f64(&brefs, bucket, k, k, 0.0);
                for m in &brefs {
                    flops::add(flops::trsm_flops(need_l, m.rows()));
                }
                let shapes: Vec<(usize, usize)> =
                    brefs.iter().map(|m| (m.rows(), m.cols())).collect();
                let dims = [bucket as i64, k as i64, k as i64];
                let out = self
                    .run("trsm", bucket, d, k, &[(lbuf, dims), (bbuf, dims)])
                    .expect("trsm artifact execution failed");
                let mats = buffer_to_batch_f64(&out, k, k, &shapes);
                for (t, m) in mats.into_iter().enumerate() {
                    b[start + t] = m;
                }
            }
        });
    }

    /// Batched Schur update through the `schur` artifacts.
    pub fn schur_self(&self, level: usize, a: &[&Matrix], c: &mut [Matrix]) {
        if c.is_empty() {
            return;
        }
        assert_eq!(a.len(), c.len());
        let need = c
            .iter()
            .map(|m| m.rows())
            .chain(a.iter().map(|m| m.cols()))
            .max()
            .unwrap();
        let fam = match self.manifest.family_for(need * 2, need) {
            Some(f) => f,
            None => {
                self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
                return self.fallback.schur_self(level, a, c);
            }
        };
        let (d, k) = fam;
        self.trace(level, "SYRK(pjrt)", c.len(), (need, need), || {
            for (start, len) in self.chunks(c.len()) {
                let bucket = self.manifest.bucket_for(len).unwrap();
                let crefs: Vec<&Matrix> = c[start..start + len].iter().collect();
                let cbuf = refs_to_buffer_f64(&crefs, bucket, k, k, 0.0);
                let abuf = refs_to_buffer_f64(&a[start..start + len], bucket, k, k, 0.0);
                for m in &a[start..start + len] {
                    flops::add(flops::gemm_flops(m.rows(), m.rows(), m.cols()));
                }
                let shapes: Vec<(usize, usize)> =
                    crefs.iter().map(|m| (m.rows(), m.cols())).collect();
                let dims = [bucket as i64, k as i64, k as i64];
                let out = self
                    .run("schur", bucket, d, k, &[(cbuf, dims), (abuf, dims)])
                    .expect("schur artifact execution failed");
                let mats = buffer_to_batch_f64(&out, k, k, &shapes);
                for (t, m) in mats.into_iter().enumerate() {
                    c[start + t] = m;
                }
            }
        });
    }

    /// Batched two-sided basis transform through the `sparsify` artifacts.
    pub fn sparsify(&self, level: usize, u: &[&Matrix], a: &[Matrix], v: &[&Matrix]) -> Vec<Matrix> {
        if a.is_empty() {
            return Vec::new();
        }
        let need = u
            .iter()
            .chain(v.iter())
            .map(|m| m.rows())
            .chain(a.iter().map(|m| m.rows().max(m.cols())))
            .max()
            .unwrap();
        let fam = match self.manifest.family_for(need, need / 2) {
            Some(f) => f,
            None => {
                self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
                return self.fallback.sparsify(level, u, a, v);
            }
        };
        let (d, k) = fam;
        self.trace(level, "GEMM2(pjrt)", a.len(), (need, need), || {
            let mut out_all: Vec<Matrix> = Vec::with_capacity(a.len());
            for (start, len) in self.chunks(a.len()) {
                let bucket = self.manifest.bucket_for(len).unwrap();
                // U, V padded with identity diagonal (orthogonality of the
                // padded transform preserves the embedded block).
                let arefs: Vec<&Matrix> = a[start..start + len].iter().collect();
                let ubuf = refs_to_buffer_f64(&u[start..start + len], bucket, d, d, 1.0);
                let abuf = refs_to_buffer_f64(&arefs, bucket, d, d, 0.0);
                let vbuf = refs_to_buffer_f64(&v[start..start + len], bucket, d, d, 1.0);
                for t in 0..len {
                    crate::batch::count_sparsify_flops(u[start + t], &a[start + t], v[start + t]);
                }
                let dims = [bucket as i64, d as i64, d as i64];
                let out = self
                    .run("sparsify", bucket, d, k, &[(ubuf, dims), (abuf, dims), (vbuf, dims)])
                    .expect("sparsify artifact execution failed");
                let shapes: Vec<(usize, usize)> = (0..len)
                    .map(|t| (u[start + t].cols(), v[start + t].cols()))
                    .collect();
                out_all.extend(buffer_to_batch_f64(&out, d, d, &shapes));
            }
            out_all
        })
    }

    /// Batched forward TRSV through the `trsv_fwd` artifacts.
    pub fn trsv_fwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        self.trsv_impl(level, l, x, "trsv_fwd");
    }

    /// Batched backward TRSV through the `trsv_bwd` artifacts.
    pub fn trsv_bwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        self.trsv_impl(level, l, x, "trsv_bwd");
    }

    /// Batched GEMV accumulate through the `gemv_*` artifacts (compiled
    /// for the substitution's `alpha = -1` update).
    pub fn gemv_acc(
        &self,
        level: usize,
        alpha: f64,
        a: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
        y: &mut [Vec<f64>],
    ) {
        if a.is_empty() {
            return;
        }
        let need = a.iter().map(|m| m.rows().max(m.cols())).max().unwrap();
        let fam = self.manifest.family_for(need * 2, need);
        if alpha != -1.0 || fam.is_none() {
            self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
            return self.fallback.gemv_acc(level, alpha, a, trans, x, y);
        }
        let (d, k) = fam.unwrap();
        let op = if trans { "gemv_tt" } else { "gemv_nt" };
        self.trace(level, "GEMV(pjrt)", a.len(), (need, need), || {
            for (start, len) in self.chunks(a.len()) {
                let bucket = self.manifest.bucket_for(len).unwrap();
                let abuf = refs_to_buffer_f64(&a[start..start + len], bucket, k, k, 0.0);
                let xbuf = vecs_to_buffer_f64(&x[start..start + len], bucket, k);
                let yrefs: Vec<&[f64]> =
                    y[start..start + len].iter().map(|v| v.as_slice()).collect();
                let ybuf = vecs_to_buffer_f64(&yrefs, bucket, k);
                for m in &a[start..start + len] {
                    flops::add(2 * (m.rows() * m.cols()) as u64);
                }
                let mdims = [bucket as i64, k as i64, k as i64];
                let vdims = [bucket as i64, k as i64, 1];
                let out = self
                    .run(op, bucket, d, k, &[(abuf, mdims), (xbuf, vdims), (ybuf, vdims)])
                    .expect("gemv artifact execution failed");
                for t in 0..len {
                    let target = &mut y[start + t];
                    let base = t * k;
                    for (s, val) in target.iter_mut().enumerate() {
                        *val = out[base + s];
                    }
                }
            }
        });
    }

    /// Batched basis application through the `basis_*` artifacts.
    pub fn apply_basis(
        &self,
        level: usize,
        u: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
    ) -> Vec<Vec<f64>> {
        if u.is_empty() {
            return Vec::new();
        }
        let need = u.iter().map(|m| m.rows()).max().unwrap();
        let fam = self.manifest.family_for(need, need / 2);
        if fam.is_none() {
            self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
            return self.fallback.apply_basis(level, u, trans, x);
        }
        let (d, k) = fam.unwrap();
        let op = if trans { "basis_t" } else { "basis_n" };
        self.trace(level, "BASIS(pjrt)", u.len(), (need, need), || {
            let mut out_all = Vec::with_capacity(u.len());
            for (start, len) in self.chunks(u.len()) {
                let bucket = self.manifest.bucket_for(len).unwrap();
                let ubuf = refs_to_buffer_f64(&u[start..start + len], bucket, d, d, 1.0);
                let xbuf = vecs_to_buffer_f64(&x[start..start + len], bucket, d);
                for m in &u[start..start + len] {
                    flops::add(2 * (m.rows() * m.cols()) as u64);
                }
                let out = self
                    .run(
                        op,
                        bucket,
                        d,
                        k,
                        &[
                            (ubuf, [bucket as i64, d as i64, d as i64]),
                            (xbuf, [bucket as i64, d as i64, 1]),
                        ],
                    )
                    .expect("basis artifact execution failed");
                for t in 0..len {
                    let m = u[start + t];
                    let out_len = if trans { m.cols() } else { m.rows() };
                    let base = t * d;
                    out_all.push(out[base..base + out_len].to_vec());
                }
            }
            out_all
        })
    }

    fn trsv_impl(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>], op: &'static str) {
        if l.is_empty() {
            return;
        }
        let need = l.iter().map(|m| m.rows()).max().unwrap();
        let fam = self.manifest.family_for(need * 2, need);
        if fam.is_none() {
            self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
            if op == "trsv_fwd" {
                return self.fallback.trsv_fwd(level, l, x);
            }
            return self.fallback.trsv_bwd(level, l, x);
        }
        let (d, k) = fam.unwrap();
        self.trace(level, "TRSV(pjrt)", l.len(), (need, 1), || {
            for (start, len) in self.chunks(l.len()) {
                let bucket = self.manifest.bucket_for(len).unwrap();
                let lbuf = refs_to_buffer_f64(&l[start..start + len], bucket, k, k, 1.0);
                let xrefs: Vec<&[f64]> =
                    x[start..start + len].iter().map(|v| v.as_slice()).collect();
                let xbuf = vecs_to_buffer_f64(&xrefs, bucket, k);
                for m in &l[start..start + len] {
                    flops::add((m.rows() * m.rows()) as u64);
                }
                let out = self
                    .run(
                        op,
                        bucket,
                        d,
                        k,
                        &[
                            (lbuf, [bucket as i64, k as i64, k as i64]),
                            (xbuf, [bucket as i64, k as i64, 1]),
                        ],
                    )
                    .expect("trsv artifact execution failed");
                for t in 0..len {
                    let target = &mut x[start + t];
                    let base = t * k;
                    for (s, val) in target.iter_mut().enumerate() {
                        *val = out[base + s];
                    }
                }
            }
        });
    }
}

impl HostKernels for PjrtBackend {
    fn potrf(&self, level: usize, blocks: &mut [Matrix]) {
        PjrtBackend::potrf(self, level, blocks);
    }
    fn trsm_right_lt(&self, level: usize, l: &[&Matrix], b: &mut [Matrix]) {
        PjrtBackend::trsm_right_lt(self, level, l, b);
    }
    fn schur_self(&self, level: usize, a: &[&Matrix], c: &mut [Matrix]) {
        PjrtBackend::schur_self(self, level, a, c);
    }
    fn sparsify(&self, level: usize, u: &[&Matrix], a: &[Matrix], v: &[&Matrix]) -> Vec<Matrix> {
        PjrtBackend::sparsify(self, level, u, a, v)
    }
    fn trsv_fwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        PjrtBackend::trsv_fwd(self, level, l, x);
    }
    fn trsv_bwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        PjrtBackend::trsv_bwd(self, level, l, x);
    }
    fn gemv_acc(
        &self,
        level: usize,
        alpha: f64,
        a: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
        y: &mut [Vec<f64>],
    ) {
        PjrtBackend::gemv_acc(self, level, alpha, a, trans, x, y);
    }
    fn apply_basis(
        &self,
        level: usize,
        u: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
    ) -> Vec<Vec<f64>> {
        PjrtBackend::apply_basis(self, level, u, trans, x)
    }
}

impl Device for PjrtBackend {
    fn new_arena(&self, capacity: usize) -> Box<dyn DeviceArena> {
        Box::new(HostArena::with_capacity(capacity))
    }

    fn launch(&self, arena: &mut dyn DeviceArena, launch: &Launch<'_>) {
        exec_host_launch(self, host_arena(arena), launch);
    }

    fn launch_solve(
        &self,
        factor: &dyn DeviceArena,
        ws: &mut dyn DeviceArena,
        launch: &Launch<'_>,
    ) {
        exec_host_solve_launch(self, host_arena_ref(factor), host_arena(ws), launch);
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{self, Side, Uplo};
    use crate::linalg::chol;
    use crate::linalg::matrix::Trans;
    use crate::linalg::norms::frob;
    use crate::util::Rng;

    fn backend() -> Option<PjrtBackend> {
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            Some(PjrtBackend::new(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn pjrt_potrf_matches_native() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(201);
        let mats: Vec<Matrix> = (0..5).map(|_| Matrix::rand_spd(20, &mut rng)).collect();
        let mut batch = mats.clone();
        be.potrf(0, &mut batch);
        for (orig, got) in mats.iter().zip(&batch) {
            let want = chol::cholesky(orig).unwrap();
            let mut d = got.clone();
            d.axpy(-1.0, &want);
            assert!(frob(&d) < 1e-9 * (1.0 + frob(&want)), "potrf mismatch {}", frob(&d));
        }
        assert!(be.stats.launches.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    }

    #[test]
    fn pjrt_trsm_matches_native() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(203);
        let ls: Vec<Matrix> = (0..3)
            .map(|_| chol::cholesky(&Matrix::rand_spd(16, &mut rng)).unwrap())
            .collect();
        let bs: Vec<Matrix> = (0..3).map(|_| Matrix::randn(12, 16, &mut rng)).collect();
        let mut batch = bs.clone();
        let lrefs: Vec<&Matrix> = ls.iter().collect();
        be.trsm_right_lt(0, &lrefs, &mut batch);
        for t in 0..3 {
            let mut want = bs[t].clone();
            blas::trsm(Side::Right, Uplo::Lower, Trans::Yes, 1.0, &ls[t], &mut want);
            let mut d = batch[t].clone();
            d.axpy(-1.0, &want);
            assert!(frob(&d) < 1e-9, "trsm mismatch {}", frob(&d));
        }
    }

    #[test]
    fn pjrt_sparsify_matches_native() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(205);
        let u = Matrix::randn(24, 24, &mut rng);
        let v = Matrix::randn(24, 24, &mut rng);
        let a = Matrix::randn(24, 24, &mut rng);
        let got = be.sparsify(0, &[&u], std::slice::from_ref(&a), &[&v]);
        let want = NativeBackend::new().sparsify(0, &[&u], std::slice::from_ref(&a), &[&v]);
        let mut d = got[0].clone();
        d.axpy(-1.0, &want[0]);
        assert!(frob(&d) < 1e-9 * (1.0 + frob(&want[0])));
    }

    #[test]
    fn pjrt_trsv_and_gemv_match_native() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(207);
        let l = chol::cholesky(&Matrix::rand_spd(10, &mut rng)).unwrap();
        let x0: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let mut x_pjrt = vec![x0.clone()];
        let mut x_native = vec![x0.clone()];
        be.trsv_fwd(0, &[&l], &mut x_pjrt);
        NativeBackend::new().trsv_fwd(0, &[&l], &mut x_native);
        for (a, b) in x_pjrt[0].iter().zip(&x_native[0]) {
            assert!((a - b).abs() < 1e-9);
        }
        be.trsv_bwd(0, &[&l], &mut x_pjrt);
        NativeBackend::new().trsv_bwd(0, &[&l], &mut x_native);
        for (a, b) in x_pjrt[0].iter().zip(&x_native[0]) {
            assert!((a - b).abs() < 1e-9);
        }
        // gemv alpha=-1 path
        let a = Matrix::randn(8, 8, &mut rng);
        let xv: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let y0: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let mut yp = vec![y0.clone()];
        let mut yn = vec![y0.clone()];
        be.gemv_acc(0, -1.0, &[&a], false, &[&xv], &mut yp);
        NativeBackend::new().gemv_acc(0, -1.0, &[&a], false, &[&xv], &mut yn);
        for (p, n) in yp[0].iter().zip(&yn[0]) {
            assert!((p - n).abs() < 1e-9);
        }
    }

    #[test]
    fn pjrt_apply_basis_matches_native() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(209);
        let u = Matrix::randn(30, 30, &mut rng);
        let x: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        for trans in [true, false] {
            let got = be.apply_basis(0, &[&u], trans, &[&x]);
            let want = NativeBackend::new().apply_basis(0, &[&u], trans, &[&x]);
            for (a, b) in got[0].iter().zip(&want[0]) {
                assert!((a - b).abs() < 1e-9, "trans={trans}");
            }
        }
    }

    #[test]
    fn pjrt_falls_back_on_oversized_blocks() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(211);
        // 100 > largest family k (64) -> fallback.
        let mut blocks = vec![Matrix::rand_spd(100, &mut rng)];
        be.potrf(0, &mut blocks);
        assert!(be.stats.fallbacks.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        // Still correct.
        for d in 0..100 {
            assert!(blocks[0][(d, d)] > 0.0);
        }
    }
}
