//! PJRT runtime: loads the AOT-compiled XLA executables (HLO text emitted
//! by `python/compile/aot.py`) and exposes them as an arena-native
//! [`crate::batch::device::Device`] backend.
//!
//! This is the repo's analog of the paper's GPU execution path: every
//! batched launch maps to one AOT executable chosen by `(op, batch-bucket,
//! shape family)`, with zero padding to constant shapes (paper §4.1) and
//! unit-diagonal augmentation for the factorization kernels (the paper's
//! batched-AXPY diagonal fill, §4.1).
//!
//! Shapes that exceed every compiled family (e.g. the dense root block)
//! fall back to the native backend — mirroring how the paper handles the
//! final `cholesky(A_00)` outside the batched path.

pub mod backend;
pub mod manifest;

pub use backend::PjrtBackend;
pub use manifest::{Artifact, Manifest};
