//! Artifact manifest parsing (artifacts/manifest.json).
//!
//! Hand-rolled JSON reader for the single fixed schema `aot.py` emits —
//! serde is unavailable offline (DESIGN.md §10).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled executable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Artifact {
    pub op: String,
    pub batch: usize,
    pub d: usize,
    pub k: usize,
    pub file: String,
}

/// Parsed manifest: artifact index plus the available shape families.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// `(op, batch, d, k)` -> artifact path.
    pub index: HashMap<(String, usize, usize, usize), PathBuf>,
    /// Distinct `(d, k)` families, ascending by `d`.
    pub families: Vec<(usize, usize)>,
    /// Distinct batch buckets, ascending.
    pub buckets: Vec<usize>,
}

impl Manifest {
    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: &Path) -> std::io::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let artifacts = parse_manifest_json(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        let mut index = HashMap::new();
        let mut families: Vec<(usize, usize)> = Vec::new();
        let mut buckets: Vec<usize> = Vec::new();
        for a in artifacts {
            if !families.contains(&(a.d, a.k)) {
                families.push((a.d, a.k));
            }
            if !buckets.contains(&a.batch) {
                buckets.push(a.batch);
            }
            index.insert((a.op.clone(), a.batch, a.d, a.k), dir.join(&a.file));
        }
        families.sort_unstable();
        buckets.sort_unstable();
        Ok(Manifest { index, families, buckets })
    }

    /// Smallest family whose padded dims fit `(need_d, need_k)`.
    pub fn family_for(&self, need_d: usize, need_k: usize) -> Option<(usize, usize)> {
        self.families
            .iter()
            .copied()
            .find(|&(d, k)| d >= need_d && k >= need_k)
    }

    /// Smallest bucket >= n (None when n exceeds the largest bucket — the
    /// caller splits the batch into largest-bucket chunks first).
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }

    /// Largest compiled bucket.
    pub fn max_bucket(&self) -> usize {
        self.buckets.last().copied().unwrap_or(0)
    }
}

/// Parse the fixed `{"artifacts": [{"op": ..., "batch": n, "d": n, "k": n,
/// "file": ...}, ...]}` schema.
pub fn parse_manifest_json(text: &str) -> Result<Vec<Artifact>, String> {
    let mut out = Vec::new();
    // Find each object between braces inside the artifacts array.
    let arr_start = text.find('[').ok_or("no artifacts array")?;
    let arr_end = text.rfind(']').ok_or("unterminated array")?;
    let body = &text[arr_start + 1..arr_end];
    for obj in body.split('}') {
        if !obj.contains('"') {
            continue;
        }
        let get_str = |key: &str| -> Option<String> {
            let pat = format!("\"{key}\"");
            let at = obj.find(&pat)? + pat.len();
            let rest = &obj[at..];
            let colon = rest.find(':')?;
            let rest = rest[colon + 1..].trim_start();
            if let Some(stripped) = rest.strip_prefix('"') {
                let end = stripped.find('"')?;
                Some(stripped[..end].to_string())
            } else {
                let end = rest
                    .find(|c: char| !(c.is_ascii_digit()))
                    .unwrap_or(rest.len());
                Some(rest[..end].to_string())
            }
        };
        let op = get_str("op").ok_or("missing op")?;
        let batch: usize = get_str("batch")
            .ok_or("missing batch")?
            .parse()
            .map_err(|e| format!("bad batch: {e}"))?;
        let d: usize = get_str("d").ok_or("missing d")?.parse().map_err(|e| format!("bad d: {e}"))?;
        let k: usize = get_str("k").ok_or("missing k")?.parse().map_err(|e| format!("bad k: {e}"))?;
        let file = get_str("file").ok_or("missing file")?;
        out.push(Artifact { op, batch, d, k, file });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"artifacts": [
 {"op": "potrf", "batch": 1, "d": 64, "k": 32, "file": "potrf_b1_d64_k32.hlo.txt"},
 {"op": "potrf", "batch": 2, "d": 64, "k": 32, "file": "potrf_b2_d64_k32.hlo.txt"},
 {"op": "trsm", "batch": 1, "d": 32, "k": 16, "file": "trsm_b1_d32_k16.hlo.txt"}
]}"#;

    #[test]
    fn parses_sample() {
        let arts = parse_manifest_json(SAMPLE).unwrap();
        assert_eq!(arts.len(), 3);
        assert_eq!(arts[0].op, "potrf");
        assert_eq!(arts[0].batch, 1);
        assert_eq!(arts[0].d, 64);
        assert_eq!(arts[2].file, "trsm_b1_d32_k16.hlo.txt");
    }

    #[test]
    fn manifest_lookup_helpers() {
        let dir = std::env::temp_dir().join("h2ulv_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.families, vec![(32, 16), (64, 32)]);
        assert_eq!(m.buckets, vec![1, 2]);
        assert_eq!(m.family_for(40, 20), Some((64, 32)));
        assert_eq!(m.family_for(10, 10), Some((32, 16)));
        assert_eq!(m.family_for(100, 10), None);
        assert_eq!(m.bucket_for(2), Some(2));
        assert_eq!(m.bucket_for(3), None);
        assert_eq!(m.max_bucket(), 2);
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.index.len() >= 100, "expected the full artifact grid");
            assert!(m.families.contains(&(64, 32)));
        }
    }
}
