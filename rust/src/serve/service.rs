//! The dispatch engine: request lines in, response lines out.
//!
//! [`Service::handle_line`] is the whole protocol — the stdin/stdout loop
//! ([`Service::serve_stream`]) and the TCP loop ([`Service::serve_tcp`])
//! are thin transports over it, in the lean command-parse/dispatch
//! engine-loop idiom. Every failure path produces a typed error *response*
//! on the same line; nothing a client sends can kill the loop.

use super::batcher::{self, Admission, BatchCounters};
use super::cache::{SessionCache, SessionEntry};
use super::protocol::{vec_json, ReqOpts, Request, ServeError};
use crate::solver::{H2Error, SolveOptions};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Service-level knobs (the CLI `serve` flags map onto these).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Resident-byte budget for the session cache (LRU evicts above it).
    pub budget_bytes: usize,
    /// Session-count cap for the cache.
    pub max_sessions: usize,
    /// Micro-batching window: single-RHS `solve` requests against one
    /// session queue this long so concurrent arrivals coalesce into one
    /// `solve_many`. 0 disables batching (every solve dispatches alone).
    pub batch_window_ms: u64,
    /// Global solve-worker budget for admission control; 0 = the
    /// machine's available parallelism.
    pub worker_budget: usize,
    /// Default per-request deadline in milliseconds; 0 = no deadline.
    /// Requests override it with `timeout_ms`.
    pub timeout_ms: u64,
    /// Idle workspace regions to keep per session when the service goes
    /// quiet (the rest are released via
    /// [`trim_workspaces`](crate::solver::H2Solver::trim_workspaces)).
    pub idle_keep_workspaces: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            budget_bytes: 256 << 20,
            max_sessions: 8,
            batch_window_ms: 2,
            worker_budget: 0,
            timeout_ms: 0,
            idle_keep_workspaces: 1,
        }
    }
}

/// The multi-tenant solve service (see the module docs).
pub struct Service {
    cfg: ServeConfig,
    cache: SessionCache,
    admission: Arc<Admission>,
    counters: Arc<BatchCounters>,
    requests: AtomicUsize,
    errors: AtomicUsize,
    shutdown: AtomicBool,
    /// Bound TCP address, if serving TCP — the shutdown path self-connects
    /// to it so the blocking `accept` loop observes the flag.
    bound: Mutex<Option<SocketAddr>>,
}

impl Service {
    pub fn new(cfg: ServeConfig) -> Arc<Service> {
        let budget = if cfg.worker_budget > 0 {
            cfg.worker_budget
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        Arc::new(Service {
            cache: SessionCache::new(cfg.budget_bytes, cfg.max_sessions),
            admission: Arc::new(Admission::new(budget)),
            counters: Arc::new(BatchCounters::default()),
            requests: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            bound: Mutex::new(None),
            cfg,
        })
    }

    /// The session cache (tests assert plan-sharing and eviction on it).
    pub fn cache(&self) -> &SessionCache {
        &self.cache
    }

    /// The micro-batching counters.
    pub fn counters(&self) -> &BatchCounters {
        &self.counters
    }

    /// Whether a `shutdown` request has been accepted.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Process one request line into one response line (no trailing
    /// newline). Never panics outward and never returns a non-JSON
    /// string: every failure is an `{"ok":false,...}` document.
    pub fn handle_line(&self, line: &str) -> String {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let result = if self.is_shutdown() {
            Err(ServeError::shutting_down())
        } else {
            Request::parse(line).and_then(|req| self.dispatch(req))
        };
        match result {
            Ok(resp) => resp.to_string_compact(),
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                e.to_json().to_string_compact()
            }
        }
    }

    fn dispatch(&self, req: Request) -> Result<Json, ServeError> {
        match req {
            Request::Build(params) => {
                let (entry, hit) = self.cache.get_or_build(&params)?;
                Ok(Json::Obj(vec![
                    ok_field(),
                    op_field("build"),
                    ("session".to_string(), Json::Num(entry.id as f64)),
                    ("cache_hit".to_string(), Json::Bool(hit)),
                    ("n".to_string(), Json::Num(entry.solver.n() as f64)),
                    ("depth".to_string(), Json::Num(entry.solver.stats().depth as f64)),
                    (
                        "plan_recordings".to_string(),
                        Json::Num(entry.solver.plan_recordings() as f64),
                    ),
                    (
                        "resident_bytes".to_string(),
                        Json::Num(entry.solver.resident_bytes() as f64),
                    ),
                ]))
            }
            Request::Solve { session, b, opts } => self.do_solve(session, b, &opts),
            Request::SolveMany { session, rhs, opts } => self.do_solve_many(session, rhs, &opts),
            Request::Evict { session } => {
                let evicted = self.cache.evict(session);
                Ok(Json::Obj(vec![
                    ok_field(),
                    op_field("evict"),
                    ("session".to_string(), Json::Num(session as f64)),
                    ("evicted".to_string(), Json::Bool(evicted)),
                ]))
            }
            Request::Stats => Ok(self.stats_json()),
            Request::Shutdown => {
                self.shutdown.store(true, Ordering::Release);
                // Unblock the accept loop: it only checks the flag between
                // connections, so hand it one.
                let bound = *self.bound.lock().unwrap_or_else(|p| p.into_inner());
                if let Some(addr) = bound {
                    let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(200));
                }
                Ok(Json::Obj(vec![ok_field(), op_field("shutdown")]))
            }
        }
    }

    fn do_solve(&self, session: u64, b: Vec<f64>, opts: &ReqOpts) -> Result<Json, ServeError> {
        let entry = self.cache.get(session).ok_or_else(|| ServeError::unknown_session(session))?;
        check_len(&entry, &b)?;
        let deadline = self.deadline(opts);
        let (report, batch_size, wait_us) =
            if opts.batchable() && self.cfg.batch_window_ms > 0 {
                // The absolute give-up instant, computed *before* submit so
                // it lower-bounds the client's actual `recv_timeout` expiry
                // — the batcher may safely drop this waiter once it passes.
                let give_up = deadline.map(|d| Instant::now() + d);
                let rx = batcher::submit(
                    &entry,
                    b,
                    Duration::from_millis(self.cfg.batch_window_ms),
                    give_up,
                    &self.admission,
                    &self.counters,
                );
                let outcome = match deadline {
                    Some(d) => rx.recv_timeout(d).map_err(|_| timeout_err(d))?,
                    None => rx
                        .recv()
                        .map_err(|_| ServeError::new("internal", "batch dispatcher vanished"))?,
                }?;
                (outcome.report, outcome.batch_size, outcome.wait_us)
            } else {
                let permit = self.admission.admit(opts.threads.unwrap_or(1));
                let sopts =
                    SolveOptions { sample_residual: opts.residual, ..Default::default() };
                let report = self.with_deadline(deadline, {
                    let entry = Arc::clone(&entry);
                    move || entry.solver.solve_opts(&b, &sopts)
                })?;
                drop(permit);
                (report, 1, 0)
            };
        self.maybe_trim(&entry);
        Ok(Json::Obj(vec![
            ok_field(),
            op_field("solve"),
            ("session".to_string(), Json::Num(entry.id as f64)),
            ("x".to_string(), vec_json(&report.x)),
            ("residual".to_string(), opt_num(report.residual)),
            ("subst_time".to_string(), Json::Num(report.subst_time)),
            ("batch_size".to_string(), Json::Num(batch_size as f64)),
            ("wait_us".to_string(), Json::Num(wait_us as f64)),
            ("report".to_string(), self.mini_report(&entry)),
        ]))
    }

    fn do_solve_many(
        &self,
        session: u64,
        rhs: Vec<Vec<f64>>,
        opts: &ReqOpts,
    ) -> Result<Json, ServeError> {
        let entry = self.cache.get(session).ok_or_else(|| ServeError::unknown_session(session))?;
        if rhs.is_empty() {
            return Err(ServeError::bad_request("'rhs' must contain at least one vector"));
        }
        for b in &rhs {
            check_len(&entry, b)?;
        }
        let permit = self.admission.admit(opts.threads.unwrap_or(rhs.len()));
        let workers = permit.granted();
        let sopts = SolveOptions {
            sample_residual: opts.residual,
            max_threads: Some(workers),
            ..Default::default()
        };
        let deadline = self.deadline(opts);
        let reports = self.with_deadline(deadline, {
            let entry = Arc::clone(&entry);
            move || entry.solver.solve_many_opts(&rhs, &sopts)
        })?;
        drop(permit);
        self.maybe_trim(&entry);
        Ok(Json::Obj(vec![
            ok_field(),
            op_field("solve_many"),
            ("session".to_string(), Json::Num(entry.id as f64)),
            ("count".to_string(), Json::Num(reports.len() as f64)),
            ("workers".to_string(), Json::Num(workers as f64)),
            ("x".to_string(), Json::Arr(reports.iter().map(|r| vec_json(&r.x)).collect())),
            (
                "residuals".to_string(),
                Json::Arr(reports.iter().map(|r| opt_num(r.residual)).collect()),
            ),
            ("report".to_string(), self.mini_report(&entry)),
        ]))
    }

    /// Effective deadline: the request override wins, else the service
    /// default (0 = none). An explicit `timeout_ms: 0` with a non-zero
    /// batch window is a deterministic timeout — the error-path hook the
    /// serve tests use.
    fn deadline(&self, opts: &ReqOpts) -> Option<Duration> {
        match opts.timeout_ms {
            Some(t) => Some(Duration::from_millis(t)),
            None if self.cfg.timeout_ms > 0 => {
                Some(Duration::from_millis(self.cfg.timeout_ms))
            }
            None => None,
        }
    }

    /// Run a solve closure, optionally under a deadline. With a deadline
    /// the solve runs on a helper thread; on timeout the request gets a
    /// typed error while the solve finishes in the background and its
    /// result is discarded (the session `Arc` keeps the factor alive).
    fn with_deadline<T: Send + 'static>(
        &self,
        deadline: Option<Duration>,
        f: impl FnOnce() -> Result<T, H2Error> + Send + 'static,
    ) -> Result<T, ServeError> {
        match deadline {
            None => f().map_err(|e| ServeError::from_h2(&e)),
            Some(d) => {
                let (tx, rx) = mpsc::channel();
                std::thread::spawn(move || {
                    let _ = tx.send(f());
                });
                rx.recv_timeout(d)
                    .map_err(|_| timeout_err(d))?
                    .map_err(|e| ServeError::from_h2(&e))
            }
        }
    }

    /// Idle-path workspace release: once nothing is in flight, sessions
    /// stop pinning the burst's workspace high-water mark.
    fn maybe_trim(&self, entry: &Arc<SessionEntry>) {
        if self.admission.in_flight() == 0 {
            entry.solver.trim_workspaces(self.cfg.idle_keep_workspaces);
        }
    }

    /// Compact per-response counters (the `report` field).
    fn mini_report(&self, entry: &Arc<SessionEntry>) -> Json {
        let cache = self.cache.stats();
        Json::Obj(vec![
            ("backend".to_string(), Json::Str(entry.solver.backend_name().to_string())),
            ("session_rhs".to_string(), Json::Num(entry.solver.solved_rhs() as f64)),
            (
                "plan_recordings".to_string(),
                Json::Num(entry.solver.plan_recordings() as f64),
            ),
            ("cache_hit_rate".to_string(), Json::Num(cache.hit_rate())),
            (
                "batches".to_string(),
                Json::Num(self.counters.dispatches.load(Ordering::Relaxed) as f64),
            ),
            (
                "coalesced".to_string(),
                Json::Num(self.counters.coalesced_requests.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    /// The `stats` response document.
    pub fn stats_json(&self) -> Json {
        let cache = self.cache.stats();
        let sessions: Vec<Json> = self
            .cache
            .entries()
            .iter()
            .map(|e| {
                Json::Obj(vec![
                    ("session".to_string(), Json::Num(e.id as f64)),
                    ("n".to_string(), Json::Num(e.solver.n() as f64)),
                    ("hits".to_string(), Json::Num(e.hits.load(Ordering::Relaxed) as f64)),
                    ("rhs".to_string(), Json::Num(e.solver.solved_rhs() as f64)),
                    (
                        "resident_bytes".to_string(),
                        Json::Num(e.solver.resident_bytes() as f64),
                    ),
                    (
                        "workspace_bytes".to_string(),
                        Json::Num(e.solver.workspace_bytes() as f64),
                    ),
                ])
            })
            .collect();
        Json::Obj(vec![
            ok_field(),
            op_field("stats"),
            (
                "cache".to_string(),
                Json::Obj(vec![
                    ("sessions".to_string(), Json::Num(cache.sessions as f64)),
                    ("resident_bytes".to_string(), Json::Num(cache.resident_bytes as f64)),
                    ("budget_bytes".to_string(), Json::Num(cache.budget_bytes as f64)),
                    ("hits".to_string(), Json::Num(cache.hits as f64)),
                    ("misses".to_string(), Json::Num(cache.misses as f64)),
                    ("evictions".to_string(), Json::Num(cache.evictions as f64)),
                    ("hit_rate".to_string(), Json::Num(cache.hit_rate())),
                ]),
            ),
            (
                "batch".to_string(),
                Json::Obj(vec![
                    (
                        "dispatches".to_string(),
                        Json::Num(self.counters.dispatches.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "coalesced_batches".to_string(),
                        Json::Num(
                            self.counters.coalesced_batches.load(Ordering::Relaxed) as f64
                        ),
                    ),
                    (
                        "coalesced_requests".to_string(),
                        Json::Num(
                            self.counters.coalesced_requests.load(Ordering::Relaxed) as f64
                        ),
                    ),
                    (
                        "batched_requests".to_string(),
                        Json::Num(
                            self.counters.batched_requests.load(Ordering::Relaxed) as f64
                        ),
                    ),
                    (
                        "max_batch".to_string(),
                        Json::Num(self.counters.max_batch.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "avg_wait_us".to_string(),
                        Json::Num(self.counters.avg_wait_us() as f64),
                    ),
                    (
                        "discarded".to_string(),
                        Json::Num(self.counters.discarded.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "admission".to_string(),
                Json::Obj(vec![
                    ("budget".to_string(), Json::Num(self.admission.budget() as f64)),
                    ("in_flight".to_string(), Json::Num(self.admission.in_flight() as f64)),
                    ("throttled".to_string(), Json::Num(self.admission.throttled() as f64)),
                ]),
            ),
            ("requests".to_string(), Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
            ("errors".to_string(), Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
            ("sessions".to_string(), Json::Arr(sessions)),
        ])
    }

    /// Serve a line stream (stdin/stdout, a TCP connection, or an
    /// in-memory stream in tests): one response line per request line,
    /// until EOF or an accepted `shutdown`.
    pub fn serve_stream<R: BufRead, W: Write>(
        self: &Arc<Self>,
        reader: R,
        mut writer: W,
    ) -> std::io::Result<()> {
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let resp = self.handle_line(&line);
            writer.write_all(resp.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            if self.is_shutdown() {
                break;
            }
        }
        Ok(())
    }

    /// Bind the TCP listener and remember its address (so `shutdown` can
    /// kick the accept loop, and so `--tcp 127.0.0.1:0` callers learn the
    /// chosen port).
    pub fn bind_tcp(&self, addr: &str) -> std::io::Result<TcpListener> {
        let listener = TcpListener::bind(addr)?;
        *self.bound.lock().unwrap_or_else(|p| p.into_inner()) = Some(listener.local_addr()?);
        Ok(listener)
    }

    /// The bound TCP address, once [`bind_tcp`](Service::bind_tcp) ran.
    pub fn bound_addr(&self) -> Option<SocketAddr> {
        *self.bound.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Accept loop: one handler thread per connection, each running
    /// [`serve_stream`](Service::serve_stream) over the socket. Returns
    /// after `shutdown` is accepted (handler threads for still-open
    /// connections are left to drain; clients that sent their requests
    /// before the shutdown response was written have their responses).
    pub fn serve_tcp(self: &Arc<Self>, listener: TcpListener) -> std::io::Result<()> {
        for stream in listener.incoming() {
            if self.is_shutdown() {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let svc = Arc::clone(self);
            std::thread::spawn(move || {
                let reader = match stream.try_clone() {
                    Ok(s) => BufReader::new(s),
                    Err(_) => return,
                };
                let _ = svc.serve_stream(reader, stream);
            });
        }
        Ok(())
    }
}

fn ok_field() -> (String, Json) {
    ("ok".to_string(), Json::Bool(true))
}

fn op_field(op: &str) -> (String, Json) {
    ("op".to_string(), Json::Str(op.to_string()))
}

fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(v) => Json::Num(v),
        None => Json::Null,
    }
}

fn timeout_err(d: Duration) -> ServeError {
    ServeError::timeout(d.as_millis() as u64)
}

fn check_len(entry: &Arc<SessionEntry>, b: &[f64]) -> Result<(), ServeError> {
    if b.len() != entry.solver.n() {
        return Err(ServeError::from_h2(&H2Error::DimensionMismatch {
            expected: entry.solver.n(),
            got: b.len(),
        }));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Scripted smoke client (CI's serve-smoke job; `h2ulv serve-client`).
// ---------------------------------------------------------------------

/// One line-oriented protocol connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client, String> {
        let writer = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let reader = BufReader::new(
            writer.try_clone().map_err(|e| format!("clone stream: {e}"))?,
        );
        Ok(Client { reader, writer })
    }

    /// Send one request line, read one response line.
    pub fn call(&mut self, line: &str) -> Result<Json, String> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| format!("send: {e}"))?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp).map_err(|e| format!("recv: {e}"))?;
        if resp.is_empty() {
            return Err("server closed the connection".to_string());
        }
        Json::parse(resp.trim_end()).map_err(|e| format!("bad response: {e} in {resp}"))
    }

    /// `call` that additionally requires `"ok":true`.
    pub fn call_ok(&mut self, line: &str) -> Result<Json, String> {
        let resp = self.call(line)?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(format!("request failed: {} -> {}", line, resp.to_string_compact()));
        }
        Ok(resp)
    }
}

/// Deterministic RHS for the smoke script.
fn smoke_rhs(n: usize, salt: usize) -> Vec<f64> {
    (0..n).map(|i| 1.0 + ((i * 37 + salt * 131) % 101) as f64 / 101.0).collect()
}

fn rhs_literal(b: &[f64]) -> String {
    vec_json(b).to_string_compact()
}

/// The CI smoke script: build two structures twice each (asserting the
/// second build of each is a cache hit with `plan_recordings == 1`), fire
/// 32 mixed `solve`/`solve_many` requests across them — including
/// concurrent single-RHS volleys that the server's micro-batcher can
/// coalesce — verify a batched solution bit-matches an unbatched one, and
/// finally require the stats counters to show at least one coalesced
/// batch. Leaves the server running unless `shutdown` is set.
pub fn run_smoke_client(addr: &str, shutdown: bool) -> Result<(), String> {
    let build_a = r#"{"op":"build","n":256,"leaf_size":32,"max_rank":16,"far_samples":32,"near_samples":32,"residual_samples":0}"#;
    let build_b = r#"{"op":"build","n":384,"leaf_size":32,"max_rank":16,"far_samples":32,"near_samples":32,"residual_samples":0}"#;
    let mut c = Client::connect(addr)?;

    // Tenant 1 and tenant 2 build the same structure: one plan recording.
    let a1 = c.call_ok(build_a)?;
    let a2 = c.call_ok(build_a)?;
    let sid_a = a1.get("session").and_then(Json::as_u64).ok_or("build: no session id")?;
    if a2.get("session").and_then(Json::as_u64) != Some(sid_a) {
        return Err("identical builds resolved to different sessions".to_string());
    }
    if a2.get("cache_hit").and_then(Json::as_bool) != Some(true) {
        return Err("second identical build was not a cache hit".to_string());
    }
    if a2.get("plan_recordings").and_then(Json::as_u64) != Some(1) {
        return Err("shared session re-recorded its plan".to_string());
    }
    let b1 = c.call_ok(build_b)?;
    let sid_b = b1.get("session").and_then(Json::as_u64).ok_or("build: no session id")?;
    let (n_a, n_b) = (256, 384);

    // 10 sequential solves alternating across the two structures (batch
    // disabled so they don't wait on the window), plus 2 solve_many with 3
    // RHS each: 12 requests.
    let mut reference_x = String::new();
    for i in 0..10 {
        let (sid, n) = if i % 2 == 0 { (sid_a, n_a) } else { (sid_b, n_b) };
        let line = format!(
            r#"{{"op":"solve","session":{sid},"b":{},"batch":false}}"#,
            rhs_literal(&smoke_rhs(n, i))
        );
        let resp = c.call_ok(&line)?;
        let x = resp.get("x").and_then(Json::as_arr).ok_or("solve: no solution")?;
        if x.len() != n {
            return Err(format!("solve returned {} entries, expected {n}", x.len()));
        }
        if i == 0 {
            reference_x = resp.get("x").unwrap().to_string_compact();
        }
    }
    for round in 0..2 {
        let rhs: Vec<String> = (0..3).map(|i| rhs_literal(&smoke_rhs(n_b, 50 + round * 3 + i))).collect();
        let line = format!(
            r#"{{"op":"solve_many","session":{sid_b},"rhs":[{}]}}"#,
            rhs.join(",")
        );
        let resp = c.call_ok(&line)?;
        if resp.get("count").and_then(Json::as_usize) != Some(3) {
            return Err("solve_many returned the wrong count".to_string());
        }
    }

    // Concurrent volleys on session A: 4 rounds x 5 clients = 20 batched
    // single-RHS requests (32 solve requests total). Retried rounds give
    // the micro-batcher repeated chances to observe >= 2 requests inside
    // one window even on slow machines.
    let mut batched_x0 = String::new();
    for round in 0..4 {
        let mut threads = Vec::new();
        for k in 0..5 {
            let addr = addr.to_string();
            threads.push(std::thread::spawn(move || -> Result<(u64, String), String> {
                let mut c = Client::connect(&addr)?;
                let salt = if round == 0 && k == 0 { 0 } else { 100 + round * 5 + k };
                let line = format!(
                    r#"{{"op":"solve","session":{sid_a},"b":{}}}"#,
                    rhs_literal(&smoke_rhs(n_a, salt))
                );
                let resp = c.call_ok(&line)?;
                let bs = resp.get("batch_size").and_then(Json::as_u64).unwrap_or(0);
                let x = resp.get("x").map(|x| x.to_string_compact()).unwrap_or_default();
                Ok((bs, x))
            }));
        }
        for (k, t) in threads.into_iter().enumerate() {
            let (_bs, x) = t.join().map_err(|_| "client thread panicked")??;
            if round == 0 && k == 0 {
                batched_x0 = x;
            }
        }
        let stats = c.call_ok(r#"{"op":"stats"}"#)?;
        let coalesced = stats
            .get("batch")
            .and_then(|b| b.get("coalesced_requests"))
            .and_then(Json::as_u64)
            .unwrap_or(0);
        if coalesced > 0 && round >= 1 {
            break;
        }
    }

    // Bit-exactness: the first volley request reused the first sequential
    // solve's RHS, and its (possibly coalesced) solution must serialize to
    // the identical byte string.
    if batched_x0 != reference_x {
        return Err("batched solution differs from the unbatched reference".to_string());
    }

    let stats = c.call_ok(r#"{"op":"stats"}"#)?;
    let coalesced = stats
        .get("batch")
        .and_then(|b| b.get("coalesced_requests"))
        .and_then(Json::as_u64)
        .unwrap_or(0);
    if coalesced == 0 {
        return Err(format!(
            "micro-batcher never coalesced a batch: {}",
            stats.to_string_compact()
        ));
    }
    let hits = stats
        .get("cache")
        .and_then(|cache| cache.get("hits"))
        .and_then(Json::as_u64)
        .unwrap_or(0);
    if hits == 0 {
        return Err("session cache recorded no hits".to_string());
    }

    // Error paths must degrade gracefully: the connection keeps serving.
    let err = c.call(r#"{"op":"solve","session":999999,"b":[1.0]}"#)?;
    if err.get("ok").and_then(Json::as_bool) != Some(false) {
        return Err("unknown session must produce a typed error".to_string());
    }
    c.call_ok(&format!(r#"{{"op":"evict","session":{sid_b}}}"#))?;

    if shutdown {
        c.call_ok(r#"{"op":"shutdown"}"#)?;
    }
    Ok(())
}
