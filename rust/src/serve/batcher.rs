//! Admission control and the request micro-batcher.
//!
//! **Admission** bounds the service's total solve-worker fan-out: each
//! request asks for the workers it could use (its RHS count) and receives
//! a grant clamped to what is left of the global budget — never less than
//! 1, so admission can throttle but not deadlock. The grant is passed to
//! [`SolveOptions::max_threads`](crate::solver::SolveOptions), capping the
//! `solve_many` atomic-cursor fan-out, and is released when the request's
//! [`Permit`] drops.
//!
//! **Micro-batching** closes the gap between the protocol's natural
//! request unit (one RHS per `solve` line) and the engine's efficient unit
//! (a wide [`solve_many`](crate::solver::H2Solver::solve_many) fan-out):
//! single-RHS requests against the same session queue briefly; the first
//! arrival becomes the *leader* and, after a configurable window, drains
//! the queue into one `solve_many` call. Coalescing changes scheduling
//! only — `solve_many` replays each RHS through the exact same
//! substitution path as a lone `solve`, so batched solutions are
//! bit-identical to unbatched ones (the property the serve tests pin).

use super::cache::SessionEntry;
use super::protocol::ServeError;
use crate::solver::{SolveOptions, SolveReport};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Global solve-worker budget with per-request grants.
pub struct Admission {
    budget: usize,
    in_flight: AtomicUsize,
    throttled: AtomicUsize,
}

impl Admission {
    /// `budget` is the total worker count the service may have solving at
    /// once (0 is clamped to 1).
    pub fn new(budget: usize) -> Admission {
        Admission {
            budget: budget.max(1),
            in_flight: AtomicUsize::new(0),
            throttled: AtomicUsize::new(0),
        }
    }

    /// Grant up to `want` workers from what is left of the budget. The
    /// grant is always at least 1 — an oversubscribed service degrades to
    /// sequential solves instead of rejecting or deadlocking — so the
    /// budget is a soft bound: `in_flight` can exceed it by at most one
    /// worker per concurrently admitted request.
    pub fn admit(self: &Arc<Self>, want: usize) -> Permit {
        let want = want.max(1);
        let mut cur = self.in_flight.load(Ordering::Acquire);
        loop {
            let grant = want.min(self.budget.saturating_sub(cur).max(1));
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + grant,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    if grant < want {
                        self.throttled.fetch_add(1, Ordering::Relaxed);
                    }
                    return Permit { adm: Arc::clone(self), granted: grant };
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Workers currently granted to in-flight requests.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// The configured budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Requests that received fewer workers than they asked for.
    pub fn throttled(&self) -> usize {
        self.throttled.load(Ordering::Relaxed)
    }
}

/// RAII worker grant — returns its workers to the budget on drop (panic
/// included, so a failed solve can't leak budget).
pub struct Permit {
    adm: Arc<Admission>,
    granted: usize,
}

impl Permit {
    /// Workers this request may use.
    pub fn granted(&self) -> usize {
        self.granted
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.adm.in_flight.fetch_sub(self.granted, Ordering::AcqRel);
    }
}

/// Service-wide micro-batching counters (surfaced in `stats` responses and
/// per-response reports).
#[derive(Default)]
pub struct BatchCounters {
    /// `solve_many` dispatches issued by the batcher.
    pub dispatches: AtomicUsize,
    /// Dispatches that coalesced ≥ 2 queued requests.
    pub coalesced_batches: AtomicUsize,
    /// Requests that rode in a coalesced (≥ 2) batch.
    pub coalesced_requests: AtomicUsize,
    /// All requests that went through the batcher.
    pub batched_requests: AtomicUsize,
    /// Largest batch dispatched so far.
    pub max_batch: AtomicUsize,
    /// Summed queue wait across batched requests, in microseconds.
    pub waited_us: AtomicU64,
    /// Results that never reached their requester: waiters whose deadline
    /// expired while queued (dropped *before* the solve, so their work is
    /// skipped, not wasted) plus post-solve sends to receivers that had
    /// already hung up. Nonzero values mean clients are timing out faster
    /// than the batch window + solve latency.
    pub discarded: AtomicUsize,
}

impl BatchCounters {
    fn record(&self, size: usize, waited_us: u64) {
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size, Ordering::Relaxed);
        if size >= 2 {
            self.coalesced_batches.fetch_add(1, Ordering::Relaxed);
            self.coalesced_requests.fetch_add(size, Ordering::Relaxed);
        }
        self.max_batch.fetch_max(size, Ordering::Relaxed);
        self.waited_us.fetch_add(waited_us, Ordering::Relaxed);
    }

    /// Mean queue wait per batched request, in microseconds.
    pub fn avg_wait_us(&self) -> u64 {
        let n = self.batched_requests.load(Ordering::Relaxed) as u64;
        if n == 0 {
            0
        } else {
            self.waited_us.load(Ordering::Relaxed) / n
        }
    }
}

/// What a batched request gets back: its own per-RHS report plus how the
/// batch treated it.
pub struct BatchOutcome {
    pub report: SolveReport,
    /// Requests coalesced into the dispatch this one rode in (1 = alone).
    pub batch_size: usize,
    /// This request's queue wait, in microseconds.
    pub wait_us: u64,
}

struct Pending {
    b: Vec<f64>,
    enqueued: Instant,
    /// Instant after which the requester has certainly stopped waiting
    /// (its `recv_timeout` started strictly after this was computed).
    /// `None` = the requester waits indefinitely.
    deadline: Option<Instant>,
    tx: mpsc::Sender<Result<BatchOutcome, ServeError>>,
}

/// Per-session queue of single-RHS requests awaiting coalescing.
#[derive(Default)]
pub struct SessionQueue {
    pending: Mutex<Vec<Pending>>,
}

/// Enqueue one RHS against `entry`'s session and return the channel its
/// result will arrive on. The caller is expected to `recv` (or
/// `recv_timeout`, for deadlines — a timed-out receiver just drops, and
/// the leader's send to it fails harmlessly).
///
/// The first request to find the queue empty is the leader: it spawns a
/// dispatch thread that sleeps for `window`, drains everything queued by
/// then, admits the batch, and runs one
/// [`solve_many_opts`](crate::solver::H2Solver::solve_many_opts) capped at
/// the admission grant. RHS dimensions must be validated against the
/// session *before* submission — the whole batch shares one fate, so a
/// malformed member would otherwise fail its neighbors.
pub fn submit(
    entry: &Arc<SessionEntry>,
    b: Vec<f64>,
    window: Duration,
    deadline: Option<Instant>,
    admission: &Arc<Admission>,
    counters: &Arc<BatchCounters>,
) -> mpsc::Receiver<Result<BatchOutcome, ServeError>> {
    let (tx, rx) = mpsc::channel();
    let is_leader = {
        let mut q = entry.queue.pending.lock().unwrap_or_else(|p| p.into_inner());
        q.push(Pending { b, enqueued: Instant::now(), deadline, tx });
        q.len() == 1
    };
    if is_leader {
        let entry = Arc::clone(entry);
        let admission = Arc::clone(admission);
        let counters = Arc::clone(counters);
        std::thread::spawn(move || {
            std::thread::sleep(window);
            dispatch(&entry, &admission, &counters);
        });
    }
    rx
}

/// Partition a drained queue into still-awaited requests and the count of
/// waiters whose deadline passed while they queued. `Pending::deadline` is
/// computed *before* the requester starts its `recv_timeout`, so
/// `now >= deadline` proves the requester's wait either has expired or will
/// expire before any solve could complete — dropping the entry (its sender
/// with it) surfaces the same timeout to the client without spending a
/// solve on an answer nobody reads.
fn split_expired(pendings: Vec<Pending>, now: Instant) -> (Vec<Pending>, usize) {
    let before = pendings.len();
    let live: Vec<Pending> = pendings
        .into_iter()
        .filter(|p| p.deadline.map_or(true, |d| now < d))
        .collect();
    let expired = before - live.len();
    (live, expired)
}

/// Drain the session queue and solve it as one batch (the leader thread's
/// body). Waiters that timed out while queued are dropped *before* the
/// dispatch and counted in [`BatchCounters::discarded`]; so are solutions
/// whose requester hung up between dispatch and delivery.
fn dispatch(entry: &Arc<SessionEntry>, admission: &Arc<Admission>, counters: &BatchCounters) {
    let pendings = std::mem::take(
        &mut *entry.queue.pending.lock().unwrap_or_else(|p| p.into_inner()),
    );
    let (pendings, expired) = split_expired(pendings, Instant::now());
    if expired > 0 {
        counters.discarded.fetch_add(expired, Ordering::Relaxed);
    }
    if pendings.is_empty() {
        return;
    }
    let size = pendings.len();
    let permit = admission.admit(size);
    let opts = SolveOptions { max_threads: Some(permit.granted()), ..Default::default() };
    let rhs: Vec<Vec<f64>> = pendings.iter().map(|p| p.b.clone()).collect();
    let solved = entry.solver.solve_many_opts(&rhs, &opts);
    let done = Instant::now();
    let waited: u64 = pendings
        .iter()
        .map(|p| done.duration_since(p.enqueued).as_micros() as u64)
        .sum();
    counters.record(size, waited);
    match solved {
        Ok(reports) => {
            for (p, report) in pendings.into_iter().zip(reports) {
                let wait_us = done.duration_since(p.enqueued).as_micros() as u64;
                // A send can only fail when the requester gave up after
                // dispatch; count the wasted solution instead of silently
                // eating it.
                if p.tx
                    .send(Ok(BatchOutcome { report, batch_size: size, wait_us }))
                    .is_err()
                {
                    counters.discarded.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Err(e) => {
            let se = ServeError::from_h2(&e);
            for p in pendings {
                let _ = p.tx.send(Err(se.clone()));
            }
        }
    }
    drop(permit);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_grants_clamp_to_the_remaining_budget() {
        let adm = Arc::new(Admission::new(4));
        let a = adm.admit(3);
        assert_eq!(a.granted(), 3);
        let b = adm.admit(3);
        assert_eq!(b.granted(), 1, "only 1 of 4 workers left");
        assert_eq!(adm.throttled(), 1);
        // Budget exhausted: the floor grant keeps requests moving.
        let c = adm.admit(2);
        assert_eq!(c.granted(), 1);
        assert_eq!(adm.in_flight(), 5, "soft bound: one floor-grant over budget");
        drop(a);
        drop(b);
        drop(c);
        assert_eq!(adm.in_flight(), 0, "permits return their workers on drop");
    }

    #[test]
    fn counters_track_coalescing() {
        let c = BatchCounters::default();
        c.record(1, 10);
        c.record(3, 300);
        assert_eq!(c.dispatches.load(Ordering::Relaxed), 2);
        assert_eq!(c.coalesced_batches.load(Ordering::Relaxed), 1);
        assert_eq!(c.coalesced_requests.load(Ordering::Relaxed), 3);
        assert_eq!(c.batched_requests.load(Ordering::Relaxed), 4);
        assert_eq!(c.max_batch.load(Ordering::Relaxed), 3);
        assert_eq!(c.avg_wait_us(), 77);
        assert_eq!(c.discarded.load(Ordering::Relaxed), 0);
    }

    fn pending(deadline: Option<Instant>) -> (Pending, mpsc::Receiver<Result<BatchOutcome, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (Pending { b: vec![1.0], enqueued: Instant::now(), deadline, tx }, rx)
    }

    #[test]
    fn split_expired_drops_only_passed_deadlines() {
        let now = Instant::now();
        let soon = now + Duration::from_secs(60);
        let (p_live, rx_live) = pending(Some(soon));
        let (p_none, rx_none) = pending(None);
        let (p_dead, rx_dead) = pending(Some(now));
        let (live, expired) = split_expired(vec![p_live, p_none, p_dead], now);
        assert_eq!(expired, 1);
        assert_eq!(live.len(), 2);
        assert!(live.iter().all(|p| p.deadline != Some(now)));
        // The expired waiter's sender is gone: its receiver observes a
        // disconnect (the client-side timeout surface), not a hang.
        assert!(matches!(rx_dead.try_recv(), Err(mpsc::TryRecvError::Disconnected)));
        drop(live);
        assert!(matches!(rx_live.try_recv(), Err(mpsc::TryRecvError::Disconnected)));
        assert!(matches!(rx_none.try_recv(), Err(mpsc::TryRecvError::Disconnected)));
    }

    #[test]
    fn split_expired_keeps_everything_without_deadlines() {
        let now = Instant::now();
        let (a, _rxa) = pending(None);
        let (b, _rxb) = pending(None);
        let (live, expired) = split_expired(vec![a, b], now);
        assert_eq!((live.len(), expired), (2, 0));
    }
}
