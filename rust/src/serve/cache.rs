//! Plan-keyed session cache with LRU eviction under a byte budget.
//!
//! Sessions are keyed by the build-config hash
//! ([`BuildParams::cfg_hash`]): two tenants issuing identical `build`
//! requests resolve to one cached [`H2Solver`] — one H² construction, one
//! plan recording, one factorization ([`H2Solver::plan_recordings`] stays
//! at 1, the acceptance assertion). Each entry also records the hash of
//! its structural [`PlanSig`](crate::plan::PlanSig), so `stats` can show
//! when distinct configs happen to share a structure (a future
//! cross-config plan-sharing hook; today the cfg hash is the key because
//! kernel *values*, not just structure, must match for a factor to be
//! reusable).
//!
//! Eviction is LRU under two bounds: a resident-byte budget (summing
//! [`H2Solver::resident_bytes`], i.e. `DeviceArena::bytes()` of each
//! session's factor region) and a session-count cap. Eviction removes the
//! entry from the cache but the `Arc` keeps in-flight solves alive; the
//! factor memory is released when the last request on it finishes.

use super::batcher::SessionQueue;
use super::protocol::{fnv1a, BuildParams, ServeError};
use crate::solver::H2Solver;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// One cached, factorized session.
pub struct SessionEntry {
    /// Protocol-visible session id.
    pub id: u64,
    /// Hash of the canonical build parameters (the cache key).
    pub cfg_hash: u64,
    /// Hash of the recorded plan's structural signature.
    pub sig_hash: u64,
    /// The shared solver: `&self` solves are concurrent, so any number of
    /// tenants use it simultaneously.
    pub solver: H2Solver,
    /// This session's micro-batching queue.
    pub queue: SessionQueue,
    /// Requests served from cache (build hits + solves).
    pub hits: AtomicUsize,
    /// LRU clock value at last use (monotonic counter, not wall time —
    /// ordering is all eviction needs).
    last_used: AtomicU64,
}

/// Cache counters for `stats` responses.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheStats {
    pub sessions: usize,
    pub resident_bytes: usize,
    pub budget_bytes: usize,
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
}

impl CacheStats {
    /// Fraction of `build` requests served from cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Inner {
    entries: Vec<Arc<SessionEntry>>,
    next_id: u64,
    clock: u64,
}

/// The multi-tenant session cache (see the module docs).
pub struct SessionCache {
    inner: Mutex<Inner>,
    budget_bytes: usize,
    max_sessions: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
}

impl SessionCache {
    /// `budget_bytes` bounds the summed resident factor bytes;
    /// `max_sessions` bounds the entry count (clamped to ≥ 1: the cache
    /// never evicts its only session mid-build, even over budget —
    /// rejecting all work would be worse than exceeding the budget by one
    /// tenant).
    pub fn new(budget_bytes: usize, max_sessions: usize) -> SessionCache {
        SessionCache {
            inner: Mutex::new(Inner { entries: Vec::new(), next_id: 1, clock: 0 }),
            budget_bytes,
            max_sessions: max_sessions.max(1),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    /// Resolve `params` to a session: a cache hit returns the existing
    /// entry (no construction, no planning, no factorization); a miss runs
    /// the full build *outside* the cache lock (other tenants keep
    /// hitting), inserts, and LRU-evicts down to the budget. Returns the
    /// entry and whether it was a hit.
    pub fn get_or_build(
        &self,
        params: &BuildParams,
    ) -> Result<(Arc<SessionEntry>, bool), ServeError> {
        let cfg_hash = params.cfg_hash();
        if let Some(entry) = self.lookup_cfg(cfg_hash) {
            return Ok((entry, true));
        }
        let solver = params.build_solver()?;
        let sig_hash = fnv1a(format!("{:?}", solver.plan().sig).as_bytes());
        let mut inner = self.lock();
        // Re-check under the lock: a racing tenant may have inserted the
        // same config while we were building. The existing entry wins (the
        // freshly built solver is dropped) so both tenants share one
        // factor.
        if let Some(entry) = find_cfg(&inner, cfg_hash) {
            touch(&mut inner, &entry);
            entry.hits.fetch_add(1, Ordering::Relaxed);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((entry, true));
        }
        let id = inner.next_id;
        inner.next_id += 1;
        let entry = Arc::new(SessionEntry {
            id,
            cfg_hash,
            sig_hash,
            solver,
            queue: SessionQueue::default(),
            hits: AtomicUsize::new(0),
            last_used: AtomicU64::new(0),
        });
        touch(&mut inner, &entry);
        inner.entries.push(Arc::clone(&entry));
        self.evict_over_budget(&mut inner);
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok((entry, false))
    }

    /// Look up a resident session by protocol id, refreshing its LRU
    /// position.
    pub fn get(&self, id: u64) -> Option<Arc<SessionEntry>> {
        let mut inner = self.lock();
        let entry = inner.entries.iter().find(|e| e.id == id).cloned()?;
        touch(&mut inner, &entry);
        entry.hits.fetch_add(1, Ordering::Relaxed);
        Some(entry)
    }

    /// Explicitly evict a session. Returns whether it was resident.
    /// In-flight solves on the entry finish normally (the `Arc` keeps the
    /// factor alive); its idle workspaces are released immediately.
    pub fn evict(&self, id: u64) -> bool {
        let removed = {
            let mut inner = self.lock();
            match inner.entries.iter().position(|e| e.id == id) {
                Some(pos) => Some(inner.entries.swap_remove(pos)),
                None => None,
            }
        };
        match removed {
            Some(entry) => {
                entry.solver.trim_workspaces(0);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Snapshot of the resident entries (stats listing).
    pub fn entries(&self) -> Vec<Arc<SessionEntry>> {
        self.lock().entries.clone()
    }

    /// Aggregate counters.
    pub fn stats(&self) -> CacheStats {
        let inner = self.lock();
        CacheStats {
            sessions: inner.entries.len(),
            resident_bytes: inner.entries.iter().map(|e| e.solver.resident_bytes()).sum(),
            budget_bytes: self.budget_bytes,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn lookup_cfg(&self, cfg_hash: u64) -> Option<Arc<SessionEntry>> {
        let mut inner = self.lock();
        let entry = find_cfg(&inner, cfg_hash)?;
        touch(&mut inner, &entry);
        entry.hits.fetch_add(1, Ordering::Relaxed);
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(entry)
    }

    /// LRU-evict until both bounds hold (the most recently used entry is
    /// always kept, so the bound is soft by at most one session).
    fn evict_over_budget(&self, inner: &mut Inner) {
        loop {
            let over_count = inner.entries.len() > self.max_sessions;
            let over_bytes = inner.entries.len() > 1
                && inner.entries.iter().map(|e| e.solver.resident_bytes()).sum::<usize>()
                    > self.budget_bytes;
            if !over_count && !over_bytes {
                return;
            }
            let lru = inner
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .expect("eviction loop only runs with entries present");
            let entry = inner.entries.swap_remove(lru);
            entry.solver.trim_workspaces(0);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn find_cfg(inner: &Inner, cfg_hash: u64) -> Option<Arc<SessionEntry>> {
    inner.entries.iter().find(|e| e.cfg_hash == cfg_hash).cloned()
}

fn touch(inner: &mut Inner, entry: &Arc<SessionEntry>) {
    inner.clock += 1;
    entry.last_used.store(inner.clock, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params(n: usize) -> BuildParams {
        BuildParams {
            n,
            leaf_size: 32,
            max_rank: 16,
            far_samples: 32,
            near_samples: 32,
            residual_samples: 0,
            ..Default::default()
        }
    }

    #[test]
    fn identical_builds_share_one_session() {
        let cache = SessionCache::new(usize::MAX, 8);
        let (a, hit_a) = cache.get_or_build(&tiny_params(96)).unwrap();
        let (b, hit_b) = cache.get_or_build(&tiny_params(96)).unwrap();
        assert!(!hit_a);
        assert!(hit_b, "second identical build must be served from cache");
        assert_eq!(a.id, b.id);
        assert!(Arc::ptr_eq(&a, &b), "both tenants hold the same entry");
        assert_eq!(a.solver.plan_recordings(), 1, "no re-planning on the shared session");
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses, stats.sessions), (1, 1, 1));
        assert!(stats.hit_rate() > 0.49 && stats.hit_rate() < 0.51);
    }

    #[test]
    fn lru_eviction_under_a_tiny_byte_budget() {
        // Budget of 1 byte: every insertion after the first pushes the
        // least-recently-used session out.
        let cache = SessionCache::new(1, 8);
        let (a, _) = cache.get_or_build(&tiny_params(64)).unwrap();
        assert!(a.solver.resident_bytes() > 1, "a real factor always exceeds 1 B");
        let (_b, _) = cache.get_or_build(&tiny_params(96)).unwrap();
        let stats = cache.stats();
        assert_eq!(stats.sessions, 1, "over-budget cache keeps only the newest session");
        assert_eq!(stats.evictions, 1);
        assert!(cache.get(a.id).is_none(), "evicted id no longer resolves");
    }

    #[test]
    fn explicit_evict_and_session_cap() {
        let cache = SessionCache::new(usize::MAX, 2);
        let (a, _) = cache.get_or_build(&tiny_params(64)).unwrap();
        let (_b, _) = cache.get_or_build(&tiny_params(96)).unwrap();
        // Touch `a` so the cap evicts the other session.
        assert!(cache.get(a.id).is_some());
        let (_c, _) = cache.get_or_build(&tiny_params(128)).unwrap();
        let stats = cache.stats();
        assert_eq!(stats.sessions, 2, "session cap holds");
        assert!(cache.get(a.id).is_some(), "recently used session survived");
        assert!(cache.evict(a.id));
        assert!(!cache.evict(a.id), "double evict reports non-resident");
    }
}
