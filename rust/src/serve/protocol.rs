//! Wire protocol of the solve service.
//!
//! One JSON document per line in both directions. Requests carry an `"op"`
//! discriminator; responses always carry `"ok"`. A failed request produces
//! `{"ok":false,"error":{"kind":...,"message":...}}` — the error is typed
//! by `kind` so scripted clients can branch without parsing prose, and the
//! taxonomy extends [`H2Error`]'s (every facade error maps to a kind via
//! [`ServeError::from_h2`]).
//!
//! # Grammar (informal)
//!
//! ```text
//! request  := build | solve | solve_many | evict | stats | shutdown
//! build    := {"op":"build", "n":4096?, "seed":42?, "geometry":"sphere"?,
//!              "kernel":"laplace"?, "leaf_size":64?, "max_rank":32?,
//!              "eta":1.0?, "rtol":0.0?, "far_samples":128?,
//!              "near_samples":96?, "backend":"native"?,
//!              "storage":"mirrored"?, "subst":"parallel"?,
//!              "residual_samples":32?, "threads":0?}
//! solve    := {"op":"solve", "session":ID, "b":[f64; n],
//!              "timeout_ms":T?, "batch":true?, "residual":bool?,
//!              "threads":N?}
//! solve_many := {"op":"solve_many", "session":ID, "rhs":[[f64; n], ...],
//!              "timeout_ms":T?, "residual":bool?, "threads":N?}
//! evict    := {"op":"evict", "session":ID}
//! stats    := {"op":"stats"}
//! shutdown := {"op":"shutdown"}
//! ```
//!
//! `?` marks optional fields with the shown defaults. `build` responds
//! with a session id; identical build parameters from any client resolve
//! to the same cached session (`"cache_hit":true`).

use crate::construct::H2Config;
use crate::geometry::Geometry;
use crate::kernels::KernelFn;
use crate::solver::{BackendSpec, FactorStorage, H2Error, H2Solver, H2SolverBuilder};
use crate::ulv::SubstMode;
use crate::util::json::Json;

/// A typed protocol-level error: `kind` is a stable machine-readable
/// discriminator, `message` is prose. Conversion from [`H2Error`] keeps
/// the facade taxonomy visible on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeError {
    pub kind: &'static str,
    pub message: String,
}

impl ServeError {
    pub fn new(kind: &'static str, message: impl Into<String>) -> ServeError {
        ServeError { kind, message: message.into() }
    }

    /// The request line was not valid JSON.
    pub fn parse(msg: impl Into<String>) -> ServeError {
        ServeError::new("parse_error", msg)
    }

    /// The request was well-formed JSON but semantically invalid.
    pub fn bad_request(msg: impl Into<String>) -> ServeError {
        ServeError::new("bad_request", msg)
    }

    /// `"op"` missing or not one of the protocol's operations.
    pub fn unknown_op(op: &str) -> ServeError {
        ServeError::new("unknown_op", format!("unknown op '{op}'"))
    }

    /// The referenced session id is not resident (never built or evicted).
    pub fn unknown_session(id: u64) -> ServeError {
        ServeError::new(
            "unknown_session",
            format!("session {id} is not resident (never built, or evicted)"),
        )
    }

    /// The request exceeded its deadline; the solve may still complete in
    /// the background, but its result is discarded.
    pub fn timeout(ms: u64) -> ServeError {
        ServeError::new("timeout", format!("request exceeded its {ms} ms deadline"))
    }

    /// The service is draining after a `shutdown` request.
    pub fn shutting_down() -> ServeError {
        ServeError::new("shutting_down", "service is shutting down")
    }

    /// Map a facade error onto the wire taxonomy.
    pub fn from_h2(err: &H2Error) -> ServeError {
        let kind = match err {
            H2Error::EmptyGeometry => "empty_geometry",
            H2Error::ProblemTooSmall { .. } => "problem_too_small",
            H2Error::InvalidConfig(_) => "invalid_config",
            H2Error::DimensionMismatch { .. } => "dimension_mismatch",
            H2Error::BackendUnavailable { .. } => "backend_unavailable",
            H2Error::NotPositiveDefinite { .. } => "not_positive_definite",
            H2Error::ConvergenceFailure { .. } => "convergence_failure",
            H2Error::PlanVerification(_) => "plan_verification",
            H2Error::Internal { .. } => "internal",
        };
        ServeError::new(kind, err.to_string())
    }

    /// The `{"ok":false,...}` response document.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("ok".to_string(), Json::Bool(false)),
            (
                "error".to_string(),
                Json::Obj(vec![
                    ("kind".to_string(), Json::Str(self.kind.to_string())),
                    ("message".to_string(), Json::Str(self.message.clone())),
                ]),
            ),
        ])
    }
}

/// Per-request solve options (the optional fields of `solve` /
/// `solve_many`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReqOpts {
    /// Deadline override in milliseconds. Absent → the service default;
    /// `0` with a non-zero batch window deterministically times out (the
    /// result cannot be ready before the window elapses).
    pub timeout_ms: Option<u64>,
    /// `false` opts a `solve` out of micro-batching (default `true`).
    pub batch: bool,
    /// Residual-sampling override (maps to
    /// [`SolveOptions::sample_residual`](crate::solver::SolveOptions)).
    pub residual: Option<bool>,
    /// Worker-thread override for this request (capped by the admission
    /// grant).
    pub threads: Option<usize>,
}

impl ReqOpts {
    fn from_json(v: &Json) -> Result<ReqOpts, ServeError> {
        let timeout_ms = match v.get("timeout_ms") {
            None => None,
            Some(t) => Some(
                t.as_u64().ok_or_else(|| {
                    ServeError::bad_request("'timeout_ms' must be a non-negative integer")
                })?,
            ),
        };
        let batch = match v.get("batch") {
            None => true,
            Some(b) => b
                .as_bool()
                .ok_or_else(|| ServeError::bad_request("'batch' must be a boolean"))?,
        };
        let residual = match v.get("residual") {
            None => None,
            Some(r) => Some(
                r.as_bool()
                    .ok_or_else(|| ServeError::bad_request("'residual' must be a boolean"))?,
            ),
        };
        let threads = match v.get("threads") {
            None => None,
            Some(t) => Some(t.as_usize().ok_or_else(|| {
                ServeError::bad_request("'threads' must be a non-negative integer")
            })?),
        };
        Ok(ReqOpts { timeout_ms, batch, residual, threads })
    }

    /// True when this request can ride in a coalesced batch: batching is
    /// on and there are no per-request overrides that would force a
    /// different [`SolveOptions`](crate::solver::SolveOptions) than the
    /// batch's.
    pub fn batchable(&self) -> bool {
        self.batch && self.residual.is_none() && self.threads.is_none()
    }
}

/// Build-request parameters, all defaulted (see the module grammar). The
/// canonical field tuple is also the session-cache key material
/// ([`BuildParams::cfg_hash`]).
#[derive(Clone, Debug, PartialEq)]
pub struct BuildParams {
    pub n: usize,
    pub seed: u64,
    pub geometry: String,
    pub kernel: String,
    pub leaf_size: usize,
    pub max_rank: usize,
    pub eta: f64,
    pub rtol: f64,
    pub far_samples: usize,
    pub near_samples: usize,
    pub backend: String,
    pub storage: String,
    pub subst: String,
    pub residual_samples: usize,
    /// Session-wide `solve_many` worker cap (0 = available parallelism).
    pub threads: usize,
}

impl Default for BuildParams {
    fn default() -> BuildParams {
        BuildParams {
            n: 4096,
            seed: 42,
            geometry: "sphere".to_string(),
            kernel: "laplace".to_string(),
            leaf_size: 64,
            max_rank: 32,
            eta: 1.0,
            rtol: 0.0,
            far_samples: 128,
            near_samples: 96,
            backend: "native".to_string(),
            storage: "mirrored".to_string(),
            subst: "parallel".to_string(),
            residual_samples: 32,
            threads: 0,
        }
    }
}

impl BuildParams {
    fn from_json(v: &Json) -> Result<BuildParams, ServeError> {
        let mut p = BuildParams::default();
        let usize_field = |key: &str, default: usize| -> Result<usize, ServeError> {
            match v.get(key) {
                None => Ok(default),
                Some(x) => x.as_usize().ok_or_else(|| {
                    ServeError::bad_request(format!("'{key}' must be a non-negative integer"))
                }),
            }
        };
        let f64_field = |key: &str, default: f64| -> Result<f64, ServeError> {
            match v.get(key) {
                None => Ok(default),
                Some(x) => x
                    .as_f64()
                    .ok_or_else(|| ServeError::bad_request(format!("'{key}' must be a number"))),
            }
        };
        let str_field = |key: &str, default: &str| -> Result<String, ServeError> {
            match v.get(key) {
                None => Ok(default.to_string()),
                Some(x) => x
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| ServeError::bad_request(format!("'{key}' must be a string"))),
            }
        };
        p.n = usize_field("n", p.n)?;
        p.seed = match v.get("seed") {
            None => p.seed,
            Some(x) => x
                .as_u64()
                .ok_or_else(|| ServeError::bad_request("'seed' must be a non-negative integer"))?,
        };
        p.geometry = str_field("geometry", &p.geometry)?;
        p.kernel = str_field("kernel", &p.kernel)?;
        p.leaf_size = usize_field("leaf_size", p.leaf_size)?;
        p.max_rank = usize_field("max_rank", p.max_rank)?;
        p.eta = f64_field("eta", p.eta)?;
        p.rtol = f64_field("rtol", p.rtol)?;
        p.far_samples = usize_field("far_samples", p.far_samples)?;
        p.near_samples = usize_field("near_samples", p.near_samples)?;
        p.backend = str_field("backend", &p.backend)?;
        p.storage = str_field("storage", &p.storage)?;
        p.subst = str_field("subst", &p.subst)?;
        p.residual_samples = usize_field("residual_samples", p.residual_samples)?;
        p.threads = usize_field("threads", p.threads)?;
        Ok(p)
    }

    /// FNV-1a over the canonical field tuple — the session-cache key. Two
    /// requests with equal hashes describe the same problem, backend, and
    /// solve policy, so they can share one factorized session.
    pub fn cfg_hash(&self) -> u64 {
        let canon = format!(
            "{}|{}|{}|{}|{}|{}|{:?}|{:?}|{}|{}|{}|{}|{}|{}|{}",
            self.n,
            self.seed,
            self.geometry,
            self.kernel,
            self.leaf_size,
            self.max_rank,
            self.eta,
            self.rtol,
            self.far_samples,
            self.near_samples,
            self.backend,
            self.storage,
            self.subst,
            self.residual_samples,
            self.threads,
        );
        fnv1a(canon.as_bytes())
    }

    /// The [`H2Config`] these parameters describe.
    pub fn to_config(&self) -> H2Config {
        H2Config {
            leaf_size: self.leaf_size,
            max_rank: self.max_rank,
            rtol: self.rtol,
            eta: self.eta,
            far_samples: self.far_samples,
            near_samples: self.near_samples,
            ..H2Config::default()
        }
    }

    /// Resolve the named pieces and run the full build (construction +
    /// plan recording + factorization). This is the cache-miss path.
    pub fn build_solver(&self) -> Result<H2Solver, ServeError> {
        let geometry = Geometry::by_name(&self.geometry, self.n, self.seed).ok_or_else(|| {
            ServeError::bad_request(format!(
                "unknown geometry '{}' (expected sphere, cube, or molecule)",
                self.geometry
            ))
        })?;
        let kernel = KernelFn::by_name(&self.kernel).ok_or_else(|| {
            ServeError::bad_request(format!(
                "unknown kernel '{}' (expected laplace, yukawa, gaussian, or matern32)",
                self.kernel
            ))
        })?;
        let backend = BackendSpec::by_name(&self.backend).ok_or_else(|| {
            ServeError::bad_request(format!("unknown backend '{}'", self.backend))
        })?;
        let storage = FactorStorage::by_name(&self.storage).ok_or_else(|| {
            ServeError::bad_request(format!(
                "unknown storage '{}' (expected mirrored or device-only)",
                self.storage
            ))
        })?;
        let subst = match self.subst.as_str() {
            "parallel" => SubstMode::Parallel,
            "naive" => SubstMode::Naive,
            other => {
                return Err(ServeError::bad_request(format!(
                    "unknown subst mode '{other}' (expected parallel or naive)"
                )))
            }
        };
        H2SolverBuilder::new(geometry, kernel)
            .config(self.to_config())
            .backend(backend)
            .subst_mode(subst)
            .factor_storage(storage)
            .residual_samples(self.residual_samples)
            .max_solve_threads(self.threads)
            .build()
            .map_err(|e| ServeError::from_h2(&e))
    }
}

/// A parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Build(BuildParams),
    Solve { session: u64, b: Vec<f64>, opts: ReqOpts },
    SolveMany { session: u64, rhs: Vec<Vec<f64>>, opts: ReqOpts },
    Evict { session: u64 },
    Stats,
    Shutdown,
}

impl Request {
    /// Parse one request line. Every failure is a typed [`ServeError`]
    /// (`parse_error` / `bad_request` / `unknown_op`) so the service can
    /// respond and keep serving.
    pub fn parse(line: &str) -> Result<Request, ServeError> {
        let v = Json::parse(line).map_err(|e| ServeError::parse(e.to_string()))?;
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| ServeError::bad_request("request must carry a string 'op' field"))?;
        match op {
            "build" => Ok(Request::Build(BuildParams::from_json(&v)?)),
            "solve" => {
                let session = session_field(&v)?;
                let b = vec_field(&v, "b")?;
                Ok(Request::Solve { session, b, opts: ReqOpts::from_json(&v)? })
            }
            "solve_many" => {
                let session = session_field(&v)?;
                let rhs_json = v
                    .get("rhs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| ServeError::bad_request("'rhs' must be an array of arrays"))?;
                let rhs = rhs_json
                    .iter()
                    .map(parse_f64_vec)
                    .collect::<Result<Vec<_>, _>>()
                    .map_err(|_| ServeError::bad_request("'rhs' must be an array of f64 arrays"))?;
                Ok(Request::SolveMany { session, rhs, opts: ReqOpts::from_json(&v)? })
            }
            "evict" => Ok(Request::Evict { session: session_field(&v)? }),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(ServeError::unknown_op(other)),
        }
    }
}

fn session_field(v: &Json) -> Result<u64, ServeError> {
    v.get("session")
        .and_then(Json::as_u64)
        .ok_or_else(|| ServeError::bad_request("request must carry a numeric 'session' id"))
}

fn vec_field(v: &Json, key: &str) -> Result<Vec<f64>, ServeError> {
    let arr = v
        .get(key)
        .ok_or_else(|| ServeError::bad_request(format!("missing '{key}' array")))?;
    parse_f64_vec(arr).map_err(|_| ServeError::bad_request(format!("'{key}' must be an f64 array")))
}

fn parse_f64_vec(v: &Json) -> Result<Vec<f64>, ()> {
    let arr = v.as_arr().ok_or(())?;
    arr.iter().map(|x| x.as_f64().ok_or(())).collect()
}

/// Serialize a vector for a response (`Json` numbers round-trip f64 values
/// bit-exactly — shortest-round-trip `Display`, `str::parse` back — so a
/// client reading the response recovers the solver's exact solution).
pub fn vec_json(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

/// FNV-1a 64-bit (the repo vendors no hash crates; stability across runs
/// matters more than collision strength for a handful of cached sessions).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_build_applies_defaults_and_overrides() {
        let req = Request::parse(r#"{"op":"build","n":512,"max_rank":16}"#).unwrap();
        match req {
            Request::Build(p) => {
                assert_eq!(p.n, 512);
                assert_eq!(p.max_rank, 16);
                assert_eq!(p.kernel, "laplace");
                assert_eq!(p.leaf_size, 64);
            }
            other => panic!("expected build, got {other:?}"),
        }
    }

    #[test]
    fn cfg_hash_distinguishes_structures() {
        let a = BuildParams { n: 512, ..Default::default() };
        let b = BuildParams { n: 1024, ..Default::default() };
        assert_eq!(a.cfg_hash(), a.clone().cfg_hash());
        assert_ne!(a.cfg_hash(), b.cfg_hash());
    }

    #[test]
    fn parse_errors_are_typed() {
        assert_eq!(Request::parse("not json").unwrap_err().kind, "parse_error");
        assert_eq!(Request::parse(r#"{"op":"frobnicate"}"#).unwrap_err().kind, "unknown_op");
        assert_eq!(Request::parse(r#"{"n":1}"#).unwrap_err().kind, "bad_request");
        assert_eq!(
            Request::parse(r#"{"op":"solve","session":1,"b":"nope"}"#).unwrap_err().kind,
            "bad_request"
        );
        assert_eq!(
            Request::parse(r#"{"op":"solve","b":[1.0]}"#).unwrap_err().kind,
            "bad_request"
        );
    }

    #[test]
    fn h2_error_mapping_covers_the_taxonomy() {
        let e = ServeError::from_h2(&H2Error::DimensionMismatch { expected: 4, got: 2 });
        assert_eq!(e.kind, "dimension_mismatch");
        assert!(e.message.contains('4'));
        let e = ServeError::from_h2(&H2Error::EmptyGeometry);
        assert_eq!(e.kind, "empty_geometry");
    }

    #[test]
    fn response_vectors_round_trip_bit_exactly() {
        let xs = vec![1.0 / 3.0, -2.718281828459045e-7, 0.1 + 0.2];
        let line = vec_json(&xs).to_string_compact();
        let back = Json::parse(&line).unwrap();
        let ys: Vec<f64> =
            back.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(xs, ys, "wire round-trip must preserve every bit");
    }
}
