//! Multi-tenant solve service over the [`crate::solver`] facade.
//!
//! Everything below the facade is built for reuse — the plan is recorded
//! once per structure ([`crate::solver::H2Solver::plan_recordings`]), the
//! factor stays device-resident, and `&self` solves fan out across a
//! workspace pool — but the CLI drives it one-shot. This subsystem turns a
//! session into a long-lived server:
//!
//! * [`protocol`] — a line-oriented JSON protocol (one request document
//!   per line, one response document per line) built on
//!   [`crate::util::json::Json`]; no serde, no framing beyond `\n`.
//! * [`cache`] — a [`SessionCache`](cache::SessionCache) keyed by the
//!   build-config hash (and recording the structural
//!   [`PlanSig`](crate::plan::PlanSig) hash), with LRU eviction under a
//!   resident-byte budget: same-structure builds from different tenants
//!   share one factorized session and never re-plan.
//! * [`batcher`] — admission control (a global worker budget with
//!   per-request grants) and a micro-batcher that coalesces queued
//!   single-RHS requests on one session into a single
//!   [`solve_many`](crate::solver::H2Solver::solve_many) fan-out within a
//!   configurable window.
//! * [`service`] — the dispatch engine: [`Service`](service::Service)
//!   turns request lines into response lines and runs the stdin/stdout
//!   and [`std::net::TcpListener`] loops. A failed request degrades to a
//!   typed error response ([`protocol::ServeError`], mapped from
//!   [`crate::solver::H2Error`]); it never kills the loop.
//!
//! The CLI front end is `h2ulv serve` (and `serve-client`, the scripted
//! smoke driver CI uses); see the README's "Solve service" section for the
//! protocol grammar and a transcript.

pub mod batcher;
pub mod cache;
pub mod protocol;
pub mod service;

pub use batcher::{Admission, BatchCounters};
pub use cache::{CacheStats, SessionCache, SessionEntry};
pub use protocol::{BuildParams, Request, ServeError};
pub use service::{Service, ServeConfig};
