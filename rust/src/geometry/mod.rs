//! Point-cloud geometry generators and spatial utilities.
//!
//! The paper evaluates on (1) a uniformly distributed spherical surface
//! (3-D Laplace) and (2) hemoglobin molecule surface meshes (3-D Yukawa).
//! The hemoglobin mesh data is not redistributable, so [`molecule`] builds
//! a synthetic molecule surface with the same character: points on the
//! boundary of a union of overlapping atom spheres along a protein-like
//! random coil (DESIGN.md §3 substitution 2).

pub mod molecule;
pub mod points;

pub use points::{Geometry, Point3};

/// Axis-aligned bounding box.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Aabb {
    pub min: Point3,
    pub max: Point3,
}

impl Aabb {
    /// Bounding box of a point set (panics on empty input).
    pub fn of(points: &[Point3]) -> Aabb {
        assert!(!points.is_empty());
        let mut min = points[0];
        let mut max = points[0];
        for p in points {
            for d in 0..3 {
                min[d] = min[d].min(p[d]);
                max[d] = max[d].max(p[d]);
            }
        }
        Aabb { min, max }
    }

    /// Center of the box.
    pub fn center(&self) -> Point3 {
        [
            0.5 * (self.min[0] + self.max[0]),
            0.5 * (self.min[1] + self.max[1]),
            0.5 * (self.min[2] + self.max[2]),
        ]
    }

    /// Half of the box diagonal — the "radius" in the paper's admissibility
    /// condition ("ratio of the maximum radius and the center distances").
    pub fn radius(&self) -> f64 {
        let mut s = 0.0;
        for d in 0..3 {
            let h = 0.5 * (self.max[d] - self.min[d]);
            s += h * h;
        }
        s.sqrt()
    }

    /// Index of the longest axis (split axis for the cluster tree).
    pub fn longest_axis(&self) -> usize {
        let mut best = 0;
        let mut len = self.max[0] - self.min[0];
        for d in 1..3 {
            let l = self.max[d] - self.min[d];
            if l > len {
                len = l;
                best = d;
            }
        }
        best
    }
}

/// Euclidean distance between two points.
#[inline]
pub fn dist(a: &Point3, b: &Point3) -> f64 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    let dz = a[2] - b[2];
    (dx * dx + dy * dy + dz * dz).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aabb_basics() {
        let pts = vec![[0.0, 0.0, 0.0], [2.0, 4.0, 6.0], [1.0, 1.0, 1.0]];
        let bb = Aabb::of(&pts);
        assert_eq!(bb.min, [0.0, 0.0, 0.0]);
        assert_eq!(bb.max, [2.0, 4.0, 6.0]);
        assert_eq!(bb.center(), [1.0, 2.0, 3.0]);
        assert_eq!(bb.longest_axis(), 2);
        assert!((bb.radius() - (1.0f64 + 4.0 + 9.0).sqrt()).abs() < 1e-14);
    }

    #[test]
    fn dist_symmetric() {
        let a = [1.0, 2.0, 2.0];
        let b = [0.0, 0.0, 0.0];
        assert!((dist(&a, &b) - 3.0).abs() < 1e-14);
        assert_eq!(dist(&a, &b), dist(&b, &a));
    }
}
