//! Synthetic molecule-surface generator.
//!
//! Substitute for the paper's hemoglobin boundary meshes (14,908 and 57,114
//! mesh points), which are not redistributable. A protein-like backbone is
//! grown as a self-avoiding-ish random coil; "atoms" (spheres) are placed
//! along it; surface points are sampled on each sphere and kept only if they
//! are not inside any other atom — i.e. points on the boundary of the union
//! of spheres. This reproduces what matters for the solver: an irregular
//! 2-D manifold embedded in 3-D with non-uniform curvature and point
//! density, which drives the off-diagonal ranks and neighbor-interaction
//! counts (DESIGN.md §3).

use super::points::{Geometry, Point3};
use crate::geometry::dist;
use crate::util::Rng;

/// Parameters for the synthetic molecule.
#[derive(Clone, Debug)]
pub struct MoleculeParams {
    /// Number of atoms along the backbone.
    pub atoms: usize,
    /// Atom (sphere) radius.
    pub radius: f64,
    /// Backbone step length between consecutive atom centers.
    pub step: f64,
    /// Target number of surface points.
    pub surface_points: usize,
}

impl Default for MoleculeParams {
    fn default() -> Self {
        MoleculeParams { atoms: 60, radius: 0.6, step: 0.5, surface_points: 4000 }
    }
}

/// Generate the synthetic molecule surface.
pub fn molecule_surface(params: &MoleculeParams, seed: u64) -> Geometry {
    let mut rng = Rng::new(seed);
    // 1. Random-coil backbone with bond-angle persistence, mildly
    //    self-avoiding (retry steps that collide with previous atoms).
    let mut centers: Vec<Point3> = Vec::with_capacity(params.atoms);
    centers.push([0.0, 0.0, 0.0]);
    let mut dir = random_unit(&mut rng);
    while centers.len() < params.atoms {
        // Perturb direction: persistent coil.
        let kick = random_unit(&mut rng);
        for d in 0..3 {
            dir[d] = 0.72 * dir[d] + 0.55 * kick[d];
        }
        normalize(&mut dir);
        let last = *centers.last().unwrap();
        let cand = [
            last[0] + params.step * dir[0],
            last[1] + params.step * dir[1],
            last[2] + params.step * dir[2],
        ];
        // Self-avoidance against all but the immediate predecessor.
        let collides = centers[..centers.len().saturating_sub(1)]
            .iter()
            .any(|c| dist(c, &cand) < 0.9 * params.radius);
        if collides {
            // Re-randomize direction and retry.
            dir = random_unit(&mut rng);
            continue;
        }
        centers.push(cand);
    }
    // 2. Rejection-sample surface points on the union of spheres.
    let per_atom_target = (params.surface_points * 3) / params.atoms.max(1) + 8;
    let mut points = Vec::with_capacity(params.surface_points * 2);
    for (ai, c) in centers.iter().enumerate() {
        for _ in 0..per_atom_target {
            let u = random_unit(&mut rng);
            let p = [
                c[0] + params.radius * u[0],
                c[1] + params.radius * u[1],
                c[2] + params.radius * u[2],
            ];
            // Keep only if on the union boundary (outside all other atoms).
            let inside_other = centers
                .iter()
                .enumerate()
                .any(|(bi, b)| bi != ai && dist(b, &p) < params.radius * 0.999);
            if !inside_other {
                points.push(p);
            }
        }
    }
    // 3. Thin to the requested count, deterministically.
    if points.len() > params.surface_points {
        let stride = points.len() as f64 / params.surface_points as f64;
        let mut thinned = Vec::with_capacity(params.surface_points);
        let mut acc = 0.0;
        while thinned.len() < params.surface_points && (acc as usize) < points.len() {
            thinned.push(points[acc as usize]);
            acc += stride;
        }
        points = thinned;
    }
    Geometry { points, name: format!("molecule{}", params.surface_points) }
}

/// Paper-sized molecule ("14,908 mesh points for [a] hemoglobin molecule"),
/// scaled by `scale` to keep runtimes manageable on CPU.
pub fn hemoglobin_like(scale: f64, seed: u64) -> Geometry {
    let n = ((14908.0 * scale) as usize).max(200);
    molecule_surface(
        &MoleculeParams { atoms: (60.0 * scale.max(0.2)) as usize + 8, surface_points: n, ..Default::default() },
        seed,
    )
}

fn random_unit(rng: &mut Rng) -> Point3 {
    loop {
        let v = [rng.normal(), rng.normal(), rng.normal()];
        let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
        if n > 1e-9 {
            return [v[0] / n, v[1] / n, v[2] / n];
        }
    }
}

fn normalize(v: &mut Point3) {
    let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
    if n > 1e-12 {
        for d in 0..3 {
            v[d] /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn molecule_point_count() {
        let g = molecule_surface(&MoleculeParams { surface_points: 1500, ..Default::default() }, 7);
        assert!(g.len() >= 1400 && g.len() <= 1500, "n={}", g.len());
    }

    #[test]
    fn molecule_points_on_union_boundary() {
        let params = MoleculeParams { atoms: 20, surface_points: 800, ..Default::default() };
        let g = molecule_surface(&params, 9);
        // Every point should be at distance ~radius from at least one atom
        // center — we can't recover centers here, but we can check the cloud
        // is a 2-D-ish manifold: it must not fill its bounding volume.
        let bb = crate::geometry::Aabb::of(&g.points);
        let vol = (bb.max[0] - bb.min[0]) * (bb.max[1] - bb.min[1]) * (bb.max[2] - bb.min[2]);
        assert!(vol > 1.0, "degenerate cloud");
        // Mean nearest-neighbor distance must be much smaller than volume^(1/3)
        // (surface sampling is denser than volume sampling at equal N).
        let sample: Vec<_> = g.points.iter().step_by(7).collect();
        let mut mean_nn = 0.0;
        for p in &sample {
            let nn = g
                .points
                .iter()
                .filter(|q| *q != *p)
                .map(|q| dist(p, q))
                .fold(f64::INFINITY, f64::min);
            mean_nn += nn;
        }
        mean_nn /= sample.len() as f64;
        let vol_spacing = (vol / g.len() as f64).cbrt();
        assert!(mean_nn < vol_spacing, "mean_nn={mean_nn} vol_spacing={vol_spacing}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = hemoglobin_like(0.05, 3);
        let b = hemoglobin_like(0.05, 3);
        assert_eq!(a.points, b.points);
    }
}
