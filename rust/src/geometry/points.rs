//! Point-cloud generators.

use crate::util::Rng;

/// A point in R³.
pub type Point3 = [f64; 3];

/// A named point cloud — the "underlying geometry of the problem that forms
/// the dense matrix" (paper §1).
#[derive(Clone, Debug)]
pub struct Geometry {
    pub points: Vec<Point3>,
    pub name: String,
}

impl Geometry {
    /// Number of points == matrix dimension N.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// N points spread quasi-uniformly on the unit sphere surface using the
    /// Fibonacci lattice ("places the mesh points evenly on the spherical
    /// surface with roughly equal spacing", paper §6.2), plus a tiny seeded
    /// jitter so duplicated runs with different seeds decorrelate.
    pub fn sphere_surface(n: usize, seed: u64) -> Geometry {
        let mut rng = Rng::new(seed);
        let golden = std::f64::consts::PI * (3.0 - 5.0f64.sqrt());
        let mut points = Vec::with_capacity(n);
        for i in 0..n {
            let y = if n > 1 { 1.0 - 2.0 * (i as f64) / ((n - 1) as f64) } else { 0.0 };
            let r = (1.0 - y * y).max(0.0).sqrt();
            let theta = golden * i as f64 + 1e-4 * rng.normal();
            points.push([r * theta.cos(), y, r * theta.sin()]);
        }
        Geometry { points, name: format!("sphere{n}") }
    }

    /// N points uniform in the unit cube — the "simple 3-D cubic geometry
    /// which requires a strong admissibility H²-matrix" (paper Figure 5).
    pub fn uniform_cube(n: usize, seed: u64) -> Geometry {
        let mut rng = Rng::new(seed);
        let points = (0..n)
            .map(|_| [rng.uniform(), rng.uniform(), rng.uniform()])
            .collect();
        Geometry { points, name: format!("cube{n}") }
    }

    /// Regular grid of `m x m x m` points in the unit cube (deterministic,
    /// used by complexity studies where exact replication matters).
    pub fn grid3d(m: usize) -> Geometry {
        let mut points = Vec::with_capacity(m * m * m);
        let h = 1.0 / (m.max(2) - 1) as f64;
        for i in 0..m {
            for j in 0..m {
                for k in 0..m {
                    points.push([i as f64 * h, j as f64 * h, k as f64 * h]);
                }
            }
        }
        Geometry { points, name: format!("grid{m}^3") }
    }

    /// Duplicate a base geometry into `copies` instances on a cubic lattice,
    /// reproducing the paper's "at most 512 duplicates of the same molecule
    /// are placed in the same domain" weak-scaling construction (§6.4).
    pub fn duplicate_lattice(&self, copies: usize, spacing: f64) -> Geometry {
        assert!(copies >= 1);
        let side = (copies as f64).cbrt().ceil() as usize;
        let mut points = Vec::with_capacity(self.len() * copies);
        let mut placed = 0;
        'outer: for ix in 0..side {
            for iy in 0..side {
                for iz in 0..side {
                    if placed == copies {
                        break 'outer;
                    }
                    let off = [ix as f64 * spacing, iy as f64 * spacing, iz as f64 * spacing];
                    for p in &self.points {
                        points.push([p[0] + off[0], p[1] + off[1], p[2] + off[2]]);
                    }
                    placed += 1;
                }
            }
        }
        Geometry { points, name: format!("{}x{}", self.name, copies) }
    }

    /// Highly non-uniform cloud: `clusters` tight Fibonacci-sphere blobs
    /// of *uneven* sizes, centered on well-separated cells of a cubic
    /// lattice. Load imbalance from "unstructured distribution of points"
    /// is the paper's stated scheduling challenge (§1): leaf boxes inside
    /// a blob are dense and near-dominated while inter-blob interactions
    /// are far-field, so near/far list sizes — and batched-kernel item
    /// shapes — vary much more than on a uniform sphere. Blob radius is
    /// small against the lattice spacing, keeping intra-blob spacing
    /// bounded below (no near-duplicate points).
    pub fn clustered(n: usize, clusters: usize, seed: u64) -> Geometry {
        assert!(clusters >= 1);
        let mut rng = Rng::new(seed ^ 0xC1A5_7E2D);
        let side = (clusters as f64).cbrt().ceil() as usize;
        let spacing = 4.0;
        let radius = 0.5;
        // Uneven split of n across clusters: weights 1..=4 per blob.
        let weights: Vec<usize> = (0..clusters).map(|_| 1 + rng.below(4)).collect();
        let total: usize = weights.iter().sum();
        let mut sizes: Vec<usize> = weights.iter().map(|w| n * w / total).collect();
        let mut assigned: usize = sizes.iter().sum();
        let mut i = 0;
        while assigned < n {
            sizes[i % clusters] += 1;
            assigned += 1;
            i += 1;
        }
        let mut points = Vec::with_capacity(n);
        let mut placed = 0;
        'outer: for ix in 0..side {
            for iy in 0..side {
                for iz in 0..side {
                    if placed == clusters {
                        break 'outer;
                    }
                    let jitter = [rng.range(-0.5, 0.5), rng.range(-0.5, 0.5), rng.range(-0.5, 0.5)];
                    let center = [
                        ix as f64 * spacing + jitter[0],
                        iy as f64 * spacing + jitter[1],
                        iz as f64 * spacing + jitter[2],
                    ];
                    let blob = Geometry::sphere_surface(sizes[placed], rng.next_u64());
                    for p in &blob.points {
                        points.push([
                            center[0] + radius * p[0],
                            center[1] + radius * p[1],
                            center[2] + radius * p[2],
                        ]);
                    }
                    placed += 1;
                }
            }
        }
        Geometry { points, name: format!("clustered{n}x{clusters}") }
    }

    /// Keep only the first `n` points ("By reading the portions of the
    /// geometry of the molecules, we create variations in the problem
    /// sizes", paper §6.4).
    pub fn truncated(&self, n: usize) -> Geometry {
        Geometry {
            points: self.points[..n.min(self.len())].to_vec(),
            name: format!("{}[..{n}]", self.name),
        }
    }

    /// Build a named point distribution: `sphere`, `cube`, or `molecule`
    /// (a hemoglobin-like cloud duplicated on a lattice and truncated to
    /// `n`, the paper's weak-scaling construction). `None` for unknown
    /// names — the shared constructor behind the CLI `--geometry` flag and
    /// the serve protocol's `build` request, so both surfaces describe the
    /// exact same problems.
    pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Geometry> {
        match name {
            "sphere" => Some(Geometry::sphere_surface(n, seed)),
            "cube" => Some(Geometry::uniform_cube(n, seed)),
            "molecule" => {
                let base = crate::geometry::molecule::hemoglobin_like(0.15, seed);
                let copies = n / base.len() + 1;
                Some(base.duplicate_lattice(copies, 6.0).truncated(n))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::dist;

    #[test]
    fn sphere_points_on_unit_sphere() {
        let g = Geometry::sphere_surface(500, 1);
        assert_eq!(g.len(), 500);
        for p in &g.points {
            let r = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
            assert!((r - 1.0).abs() < 5e-3, "r={r}");
        }
    }

    #[test]
    fn sphere_roughly_uniform() {
        // Nearest-neighbor distances should cluster around the ideal
        // spacing ~ sqrt(4π/N).
        let n = 400;
        let g = Geometry::sphere_surface(n, 2);
        let ideal = (4.0 * std::f64::consts::PI / n as f64).sqrt();
        let mut max_nn = 0.0f64;
        for (i, p) in g.points.iter().enumerate() {
            let nn = g
                .points
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != i)
                .map(|(_, q)| dist(p, q))
                .fold(f64::INFINITY, f64::min);
            max_nn = max_nn.max(nn);
        }
        assert!(max_nn < 3.0 * ideal, "max nn dist {max_nn} vs ideal {ideal}");
    }

    #[test]
    fn cube_in_bounds() {
        let g = Geometry::uniform_cube(1000, 3);
        for p in &g.points {
            for d in 0..3 {
                assert!((0.0..1.0).contains(&p[d]));
            }
        }
    }

    #[test]
    fn grid_size() {
        let g = Geometry::grid3d(4);
        assert_eq!(g.len(), 64);
    }

    #[test]
    fn duplicate_lattice_counts_and_offsets() {
        let base = Geometry::sphere_surface(50, 4);
        let dup = base.duplicate_lattice(8, 4.0);
        assert_eq!(dup.len(), 400);
        // Copies must not overlap: min distance between copy centroids >= spacing.
        let centroid = |pts: &[Point3]| -> Point3 {
            let mut c = [0.0; 3];
            for p in pts {
                for d in 0..3 {
                    c[d] += p[d];
                }
            }
            for d in 0..3 {
                c[d] /= pts.len() as f64;
            }
            c
        };
        let c0 = centroid(&dup.points[0..50]);
        let c1 = centroid(&dup.points[50..100]);
        assert!(dist(&c0, &c1) >= 3.9);
    }

    #[test]
    fn clustered_counts_and_separation() {
        let n = 300;
        let g = Geometry::clustered(n, 4, 9);
        assert_eq!(g.len(), n);
        // Every point sits inside some blob (radius 0.5 + jitter 0.5
        // around a lattice cell), so nearest-neighbor distances split into
        // a tight intra-blob scale far below the 4.0 lattice spacing.
        let mut max_nn = 0.0f64;
        for (i, p) in g.points.iter().enumerate() {
            let nn = g
                .points
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != i)
                .map(|(_, q)| dist(p, q))
                .fold(f64::INFINITY, f64::min);
            assert!(nn > 1e-6, "near-duplicate points break kernel matrices");
            max_nn = max_nn.max(nn);
        }
        assert!(max_nn < 2.0, "blobs must be internally dense, got nn {max_nn}");
    }

    #[test]
    fn clustered_sizes_are_uneven() {
        // The generator's point of existence: per-blob populations differ,
        // inducing the load imbalance the paper calls out. Blob membership
        // recovered by rounding to the nearest lattice cell.
        let g = Geometry::clustered(400, 8, 11);
        let mut counts = std::collections::HashMap::new();
        for p in &g.points {
            let cell = (
                (p[0] / 4.0).round() as i64,
                (p[1] / 4.0).round() as i64,
                (p[2] / 4.0).round() as i64,
            );
            *counts.entry(cell).or_insert(0usize) += 1;
        }
        let min = counts.values().min().copied().unwrap();
        let max = counts.values().max().copied().unwrap();
        assert!(counts.len() >= 2, "expected multiple blobs");
        assert!(max > min, "cluster sizes must be uneven: min {min} == max {max}");
    }

    #[test]
    fn truncated_prefix() {
        let g = Geometry::uniform_cube(100, 5);
        let t = g.truncated(10);
        assert_eq!(t.len(), 10);
        assert_eq!(t.points[3], g.points[3]);
    }
}
