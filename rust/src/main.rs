//! `h2ulv` CLI — leader entrypoint for the solver, the figure harness, and
//! diagnostics. Unknown commands print usage.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(h2ulv::cli::run(argv));
}
