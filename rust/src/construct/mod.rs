//! H²-matrix construction with the paper's *factorization basis*
//! (paper §3.4-§3.5, Algorithm 1).
//!
//! For every box `B_i` (level by level, leaves upward) we build a sample
//! matrix `M_i = [A_Far | A_Close · A_cc⁻¹]`:
//!
//! * `A_Far  = G(dofs_i, S_F)` — sampled far-field columns, the classical
//!   low-rank shared basis content;
//! * `A_Close · A_cc⁻¹ = G(dofs_i, S_C) · G(S_C, S_C)⁻¹` — the
//!   **factorization basis** content: it upper-bounds (in rank) every Schur
//!   complement `A_ji A_ii⁻¹ A_ik` that can arise during the ULV
//!   factorization (paper eq 22-23), so fill-in never needs re-compression.
//!
//! A row interpolative decomposition of `M_i` yields skeleton points `SK_i`
//! and an interpolation operator `T_i`; QR-orthogonalizing `W_i T_i`
//! (`W_i` = child-R weighting at interior nodes) gives the square orthogonal
//! `U_i = [U^S | U^R]` that the ULV factorization applies from both sides,
//! plus the `R_i` weight that enters the couplings
//! `Ŝ_ij = R_i G(SK_i, SK_j) R_jᵀ`.

pub mod basis;
pub mod sampling;

pub use basis::{build_bases, NodeBasis};

/// Construction / factorization configuration.
#[derive(Clone, Debug)]
pub struct H2Config {
    /// Maximum points per leaf box.
    pub leaf_size: usize,
    /// Maximum basis rank per box.
    pub max_rank: usize,
    /// Relative truncation tolerance for the ID (0.0 = fixed-rank, the
    /// paper's Figure 18 configuration).
    pub rtol: f64,
    /// Admissibility condition number (paper: 0.0 = HSS ... 3.0).
    pub eta: f64,
    /// Number of sampled far-field points per box (0 = use *all*
    /// well-separated points: best accuracy, O(N²) construction — the
    /// paper's fig 18 setting "far-field sampling disabled").
    pub far_samples: usize,
    /// Number of sampled near-field points per box for the factorization
    /// basis (pre-factorization, paper §3.5).
    pub near_samples: usize,
    /// Gauss-Seidel iterations for approximating `A_Close · A_cc⁻¹`
    /// without factorizing `A_cc` (paper §3.5: "one or two ... produce a
    /// sufficiently accurate approximation"). 0 = exact Cholesky solve.
    pub gauss_seidel_iters: usize,
    /// Include the factorization basis (near-field) content in the shared
    /// basis. Disabling reproduces a conventional H² basis — used by the
    /// ablation benchmarks to show why the factorization basis matters.
    pub factorization_basis: bool,
    /// RNG seed for sampling.
    pub seed: u64,
}

impl Default for H2Config {
    fn default() -> Self {
        H2Config {
            leaf_size: 64,
            max_rank: 24,
            rtol: 0.0,
            eta: 1.0,
            far_samples: 128,
            near_samples: 96,
            gauss_seidel_iters: 2,
            factorization_basis: true,
            seed: 0xA11CE,
        }
    }
}

impl H2Config {
    /// HSS configuration: weak admissibility (paper Figure 18's comparator,
    /// "the HSS matrix is a subset of the more general H² matrix").
    pub fn hss(mut self) -> Self {
        self.eta = 0.0;
        self
    }
}
