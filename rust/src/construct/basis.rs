//! Shared-basis construction (paper Algorithm 1), including the
//! pre-factorization that folds the factorization basis into the shared
//! low-rank basis.

use super::sampling::{near_ranges, sample_complement, sample_union};
use super::H2Config;
use crate::kernels::KernelFn;
use crate::linalg::blas::{self, Side, Uplo};
use crate::linalg::chol;
use crate::linalg::matrix::{Matrix, Trans};
use crate::linalg::qr::{orthogonalize_basis, row_id};
use crate::metrics::flops;
use crate::tree::{ClusterTree, LevelLists};
use crate::util::{par_map, Rng};

/// Per-node basis data produced by the construction phase.
#[derive(Clone, Debug)]
pub struct NodeBasis {
    /// Square orthogonal transform `U_i = [U^S | U^R]` (`ndof x ndof`).
    /// The first [`rank`](NodeBasis::rank) columns are the skeleton basis.
    pub u: Matrix,
    /// Skeleton rank `k_i`.
    pub rank: usize,
    /// Upper-triangular weight (`k x k`) from QR of the (weighted)
    /// interpolation operator; enters couplings `Ŝ = R_i S R_jᵀ`.
    pub r: Matrix,
    /// Interpolation operator `T_i` (`ndof x k`, identity rows at the
    /// skeleton DOFs) — used by the O(N) matvec and dense reconstruction.
    pub t: Matrix,
    /// Skeleton DOF positions *within this node's DOF list*.
    pub dof_skel: Vec<usize>,
    /// Global (tree-ordered) point indices of this node's DOFs.
    pub dofs: Vec<usize>,
    /// Global point indices of the skeleton (`dofs[dof_skel[..]]`).
    pub skeleton: Vec<usize>,
}

impl NodeBasis {
    /// Number of DOFs this node exposes to its level (`n_i`).
    pub fn ndof(&self) -> usize {
        self.dofs.len()
    }

    /// Redundant dimension `n_i - k_i`.
    pub fn nred(&self) -> usize {
        self.ndof() - self.rank
    }
}

/// Gauss-Seidel approximation of `X = B · A⁻¹` (i.e. solve `X A = B`) for
/// symmetric positive definite `A`, without factorizing `A` (paper §3.5:
/// "we used the Gauss-Seidel iterative method for approximating the
/// contents of A_ji A_ii⁻¹ without explicitly factorizing it").
///
/// Works on the transposed system `A Xᵀ = Bᵀ` (A symmetric), sweeping
/// `iters` times from a zero initial guess.
pub fn gauss_seidel_right(a: &Matrix, b: &Matrix, iters: usize) -> Matrix {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    assert_eq!(b.cols(), n);
    let m = b.rows();
    // Y = Xᵀ (n x m), solve A Y = Bᵀ. A is symmetric, so row i of A is
    // column i — contiguous access (perf pass: slice dot instead of
    // strided row walk).
    let mut y = Matrix::zeros(n, m);
    for _ in 0..iters.max(1) {
        for i in 0..n {
            let arow = a.col(i).to_vec(); // = row i by symmetry
            let aii = arow[i];
            for c in 0..m {
                // b^T[i, c] = b[c, i]
                let ycol = y.col_mut(c);
                let mut s = b[(c, i)];
                s -= blas::dot(&arow, ycol);
                s += aii * ycol[i]; // remove the j == i term
                ycol[i] = s / aii;
            }
        }
    }
    flops::add(2 * n as u64 * n as u64 * m as u64 * iters as u64);
    y.transpose()
}

/// Exact `X = B · A⁻¹` through Cholesky (`A` SPD).
pub fn exact_right_inverse(a: &Matrix, b: &Matrix) -> Matrix {
    let l = chol::cholesky(a).expect("near-field sample gram must be SPD");
    // X A = B  =>  A Xᵀ = Bᵀ  =>  Xᵀ = A⁻¹ Bᵀ.
    let mut y = b.transpose();
    let n = a.rows();
    flops::add(n as u64 * n as u64 * n as u64 / 3 + 2 * n as u64 * n as u64 * b.rows() as u64);
    blas::trsm(Side::Left, Uplo::Lower, Trans::No, 1.0, &l, &mut y);
    blas::trsm(Side::Left, Uplo::Lower, Trans::Yes, 1.0, &l, &mut y);
    y.transpose()
}

/// Build the bases for every node of every level (leaves upward).
///
/// Returns `bases[level][index]`; levels `1..=depth` get real bases, level 0
/// (root) gets a placeholder full-rank identity basis (the root block is
/// factorized densely, paper Algorithm 2 line 22).
pub fn build_bases(
    tree: &ClusterTree,
    lists: &[LevelLists],
    kernel: &KernelFn,
    cfg: &H2Config,
) -> Vec<Vec<NodeBasis>> {
    let depth = tree.depth;
    let mut bases: Vec<Vec<NodeBasis>> = Vec::with_capacity(depth + 1);
    bases.resize_with(depth + 1, Vec::new);
    for level in (1..=depth).rev() {
        let width = tree.width(level);
        let child_bases: Option<&Vec<NodeBasis>> = if level < depth { Some(&bases[level + 1]) } else { None };
        let level_bases: Vec<NodeBasis> = par_map(width, |i| {
            // Per-node RNG stream: deterministic, order-independent.
            let mut rng = Rng::new(cfg.seed ^ ((level as u64) << 32) ^ i as u64);
            build_node_basis(tree, lists, kernel, cfg, level, i, child_bases, &mut rng)
        });
        bases[level] = level_bases;
    }
    // Root placeholder: identity over the children's skeleton DOFs (or over
    // all points when depth == 0).
    let root_dofs: Vec<usize> = if depth == 0 {
        (0..tree.points.len()).collect()
    } else {
        let c0 = &bases[1][0];
        let c1 = &bases[1][1];
        c0.skeleton.iter().chain(c1.skeleton.iter()).copied().collect()
    };
    let n0 = root_dofs.len();
    bases[0] = vec![NodeBasis {
        u: Matrix::eye(n0),
        rank: n0,
        r: Matrix::eye(n0),
        t: Matrix::eye(n0),
        dof_skel: (0..n0).collect(),
        skeleton: root_dofs.clone(),
        dofs: root_dofs,
    }];
    bases
}

/// Build one node's basis (Algorithm 1 body).
fn build_node_basis(
    tree: &ClusterTree,
    lists: &[LevelLists],
    kernel: &KernelFn,
    cfg: &H2Config,
    level: usize,
    i: usize,
    child_bases: Option<&Vec<NodeBasis>>,
    rng: &mut Rng,
) -> NodeBasis {
    let node = tree.node(level, i);
    let n_pts = tree.points.len();
    // DOFs of this node: leaf => own points; interior => children skeletons.
    let (dofs, weight): (Vec<usize>, Option<(Matrix, Matrix)>) = match child_bases {
        None => ((node.begin..node.end).collect(), None),
        Some(cb) => {
            let c0 = &cb[2 * i];
            let c1 = &cb[2 * i + 1];
            let dofs: Vec<usize> =
                c0.skeleton.iter().chain(c1.skeleton.iter()).copied().collect();
            (dofs, Some((c0.r.clone(), c1.r.clone())))
        }
    };
    let ndof = dofs.len();

    // --- Sample far field (S_F) and near field (S_C). ---
    let nr = near_ranges(tree, &lists[level], level, i);
    let s_far = sample_complement(n_pts, &nr, cfg.far_samples, rng);
    let s_close = if cfg.factorization_basis {
        sample_union(&nr, (node.begin, node.end), cfg.near_samples, rng)
    } else {
        Vec::new()
    };

    // --- Assemble the sample matrix M = [A_Far | A_Close · A_cc⁻¹]. ---
    let a_far = kernel.block_idx(&tree.points, &dofs, &s_far);
    let m = if s_close.is_empty() {
        a_far
    } else {
        let a_cc = kernel.block_idx(&tree.points, &s_close, &s_close);
        let a_close_raw = kernel.block_idx(&tree.points, &dofs, &s_close);
        // Pre-factorization: A_Close ← G(B_i, S_C) · A_cc⁻¹
        // (Gauss-Seidel approximation per paper §3.5, or exact Cholesky).
        let a_close = if cfg.gauss_seidel_iters > 0 {
            gauss_seidel_right(&a_cc, &a_close_raw, cfg.gauss_seidel_iters)
        } else {
            exact_right_inverse(&a_cc, &a_close_raw)
        };
        if a_far.cols() == 0 {
            a_close
        } else {
            // Scale balance: the diagonal regularization (A_ii ~ 1e3) makes
            // the factorization-basis columns ~1e-3 of the far-field
            // columns, so a norm-greedy CPQR would never pivot into them
            // and the fill-in suppression would silently vanish. Rescale
            // the near part so its strongest column matches the far part's
            // strongest column; only the *span* matters for the basis, not
            // the scale.
            let col_max = |m: &Matrix| -> f64 {
                let mut best: f64 = 0.0;
                for j in 0..m.cols() {
                    let n = blas::dot(m.col(j), m.col(j)).sqrt();
                    best = best.max(n);
                }
                best
            };
            let nf = col_max(&a_far);
            let nc = col_max(&a_close);
            let mut scaled = a_close;
            if nc > 0.0 && nf > 0.0 {
                scaled.scale(nf / nc);
            }
            a_far.hcat(&scaled)
        }
    };
    flops::add(2 * (ndof * m.cols() * cfg.max_rank.min(ndof)) as u64); // ID cost estimate

    // --- Row ID: skeleton + interpolation. ---
    let max_rank = cfg.max_rank.min(ndof);
    let id = if m.cols() == 0 {
        // No sampled field at all (tiny problems): full-rank identity basis.
        crate::linalg::qr::RowId { skeleton: (0..ndof).collect(), t: Matrix::eye(ndof) }
    } else {
        row_id(&m, cfg.rtol, max_rank)
    };
    let rank = id.skeleton.len();

    // --- Weight by children R factors at interior nodes, orthogonalize. ---
    let w_t = match &weight {
        None => id.t.clone(),
        Some((r0, r1)) => {
            // W = blockdiag(R_c0, R_c1); basis operates on child-transformed
            // coordinates (DESIGN.md §4).
            let k0 = r0.rows();
            let mut wt = Matrix::zeros(ndof, rank);
            // top block: R_c0 * T[0..k0, :]
            let t_top = id.t.submatrix(0, 0, k0, rank);
            let mut top = Matrix::zeros(k0, rank);
            blas::gemm(1.0, r0, Trans::No, &t_top, Trans::No, 0.0, &mut top);
            wt.set_submatrix(0, 0, &top);
            let k1 = r1.rows();
            let t_bot = id.t.submatrix(k0, 0, k1, rank);
            let mut bot = Matrix::zeros(k1, rank);
            blas::gemm(1.0, r1, Trans::No, &t_bot, Trans::No, 0.0, &mut bot);
            wt.set_submatrix(k0, 0, &bot);
            wt
        }
    };
    let (u, r) = orthogonalize_basis(&w_t);
    flops::add(2 * (ndof * ndof * rank) as u64);

    let skeleton: Vec<usize> = id.skeleton.iter().map(|&d| dofs[d]).collect();
    NodeBasis { u, rank, r, t: id.t, dof_skel: id.skeleton, dofs, skeleton }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Geometry;
    use crate::linalg::norms::frob;
    use crate::tree::interaction_lists;

    #[test]
    fn gauss_seidel_close_to_exact() {
        let mut rng = Rng::new(81);
        // Diagonally dominant SPD (like kernel matrices with diag 1e3).
        let mut a = Matrix::rand_spd(12, &mut rng);
        for i in 0..12 {
            a[(i, i)] += 100.0;
        }
        let b = Matrix::randn(5, 12, &mut rng);
        let exact = exact_right_inverse(&a, &b);
        let gs2 = gauss_seidel_right(&a, &b, 2);
        let mut d = gs2.clone();
        d.axpy(-1.0, &exact);
        assert!(
            frob(&d) < 0.05 * frob(&exact),
            "2 GS sweeps should be close for diagonally dominant A: {}",
            frob(&d) / frob(&exact)
        );
    }

    #[test]
    fn exact_right_inverse_identity() {
        let mut rng = Rng::new(83);
        let a = Matrix::rand_spd(8, &mut rng);
        let b = Matrix::randn(3, 8, &mut rng);
        let x = exact_right_inverse(&a, &b);
        let mut rec = Matrix::zeros(3, 8);
        blas::gemm(1.0, &x, Trans::No, &a, Trans::No, 0.0, &mut rec);
        rec.axpy(-1.0, &b);
        assert!(frob(&rec) < 1e-9 * frob(&b));
    }

    fn basis_sanity(bases: &[Vec<NodeBasis>], tree: &ClusterTree) {
        for level in 1..=tree.depth {
            for (i, nb) in bases[level].iter().enumerate() {
                let n = nb.ndof();
                assert_eq!(nb.u.rows(), n);
                assert_eq!(nb.u.cols(), n);
                assert!(nb.rank <= n);
                assert_eq!(nb.skeleton.len(), nb.rank);
                // U orthogonal.
                let mut utu = Matrix::zeros(n, n);
                blas::gemm(1.0, &nb.u, Trans::Yes, &nb.u, Trans::No, 0.0, &mut utu);
                utu.axpy(-1.0, &Matrix::eye(n));
                assert!(frob(&utu) < 1e-10, "level {level} node {i} U not orthogonal");
                // Skeleton points belong to the node's range at leaf level.
                if level == tree.depth {
                    let node = tree.node(level, i);
                    for &s in &nb.skeleton {
                        assert!(s >= node.begin && s < node.end);
                    }
                }
            }
        }
    }

    #[test]
    fn bases_build_and_are_orthogonal() {
        let g = Geometry::sphere_surface(512, 85);
        let t = ClusterTree::build(&g, 64);
        let cfg = H2Config { max_rank: 16, far_samples: 64, near_samples: 48, ..Default::default() };
        let lists = interaction_lists(&t, cfg.eta);
        let k = KernelFn::laplace();
        let bases = build_bases(&t, &lists, &k, &cfg);
        basis_sanity(&bases, &t);
        // Interior nodes exist and have nested skeletons.
        for level in (1..t.depth).rev() {
            for (i, nb) in bases[level].iter().enumerate() {
                let c0 = &bases[level + 1][2 * i];
                let c1 = &bases[level + 1][2 * i + 1];
                let child_sk: std::collections::HashSet<usize> =
                    c0.skeleton.iter().chain(c1.skeleton.iter()).copied().collect();
                for &s in &nb.skeleton {
                    assert!(child_sk.contains(&s), "skeleton not nested");
                }
            }
        }
    }

    #[test]
    fn leaf_basis_captures_far_field() {
        // U^S must span the dominant row space of the box's far block:
        // || (I - U^S U^Sᵀ) A_far || should be small relative to ||A_far||.
        let g = Geometry::sphere_surface(512, 87);
        let t = ClusterTree::build(&g, 64);
        let cfg = H2Config {
            max_rank: 24,
            far_samples: 0, // all far points: best accuracy
            near_samples: 48,
            ..Default::default()
        };
        let lists = interaction_lists(&t, cfg.eta);
        let kern = KernelFn::laplace();
        let bases = build_bases(&t, &lists, &kern, &cfg);
        let l = t.depth;
        let i = 0;
        let nb = &bases[l][i];
        let node = t.node(l, i);
        // Build the true far block (all points in far-admissible boxes).
        let far_cols: Vec<usize> = lists[l]
            .far_of_row(i)
            .flat_map(|j| {
                let nj = t.node(l, j);
                nj.begin..nj.end
            })
            .collect();
        assert!(!far_cols.is_empty());
        let rows: Vec<usize> = (node.begin..node.end).collect();
        let a_far = kern.block_idx(&t.points, &rows, &far_cols);
        let us = nb.u.submatrix(0, 0, nb.ndof(), nb.rank);
        // residual = A_far - U^S (U^Sᵀ A_far)
        let mut proj = Matrix::zeros(nb.rank, a_far.cols());
        blas::gemm(1.0, &us, Trans::Yes, &a_far, Trans::No, 0.0, &mut proj);
        let mut rec = Matrix::zeros(a_far.rows(), a_far.cols());
        blas::gemm(1.0, &us, Trans::No, &proj, Trans::No, 0.0, &mut rec);
        rec.axpy(-1.0, &a_far);
        let rel = frob(&rec) / frob(&a_far);
        // Optimal rank-24 SVD error for this block is ~8e-3 (sphere far
        // field decays slowly at eta=1); the ID should be within ~4x.
        assert!(rel < 4e-2, "basis misses far field: rel={rel}");
    }
}
