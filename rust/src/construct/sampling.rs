//! Far/near-field point sampling for the construction phase.
//!
//! Sampling the far field bounds construction cost at O(N) ("any constant
//! sample size reduces this complexity to O(N)", paper §3.4); sampling the
//! near field bounds the pre-factorization overhead (paper §3.5, Figure 8).

use crate::tree::{ClusterTree, LevelLists};
use crate::util::Rng;

/// Contiguous index ranges (tree ordering) owned by the near boxes of a
/// node, *including* the node itself.
pub fn near_ranges(tree: &ClusterTree, lists: &LevelLists, level: usize, i: usize) -> Vec<(usize, usize)> {
    let mut ranges: Vec<(usize, usize)> = lists
        .near_of_row(i)
        .map(|j| {
            let nj = tree.node(level, j);
            (nj.begin, nj.end)
        })
        .collect();
    ranges.sort_unstable();
    ranges
}

/// Sample up to `k` indices uniformly from `[0, n)` minus the union of
/// `ranges` (sorted, disjoint). Returns all complement points when the
/// complement is smaller than `k` or when `k == 0` (sampling disabled).
pub fn sample_complement(
    n: usize,
    ranges: &[(usize, usize)],
    k: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    // Build the gap list.
    let mut gaps: Vec<(usize, usize)> = Vec::with_capacity(ranges.len() + 1);
    let mut cursor = 0;
    for &(b, e) in ranges {
        if b > cursor {
            gaps.push((cursor, b));
        }
        cursor = cursor.max(e);
    }
    if cursor < n {
        gaps.push((cursor, n));
    }
    let total: usize = gaps.iter().map(|&(b, e)| e - b).sum();
    if total == 0 {
        return Vec::new();
    }
    if k == 0 || total <= k {
        // Take everything.
        let mut out = Vec::with_capacity(total);
        for &(b, e) in &gaps {
            out.extend(b..e);
        }
        return out;
    }
    // Sample k distinct offsets in [0, total), then map through the gaps.
    let offsets = rng.sample_indices(total, k);
    let mut out = Vec::with_capacity(k);
    for off in offsets {
        let mut rem = off;
        for &(b, e) in &gaps {
            let len = e - b;
            if rem < len {
                out.push(b + rem);
                break;
            }
            rem -= len;
        }
    }
    out.sort_unstable();
    out
}

/// Sample up to `k` indices from the union of `ranges` (the near field),
/// excluding range `self_range` (the box's own points).
pub fn sample_union(
    ranges: &[(usize, usize)],
    self_range: (usize, usize),
    k: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let filtered: Vec<(usize, usize)> = ranges
        .iter()
        .copied()
        .filter(|&r| r != self_range)
        .collect();
    let total: usize = filtered.iter().map(|&(b, e)| e - b).sum();
    if total == 0 {
        return Vec::new();
    }
    if k == 0 || total <= k {
        let mut out = Vec::with_capacity(total);
        for &(b, e) in &filtered {
            out.extend(b..e);
        }
        return out;
    }
    let offsets = rng.sample_indices(total, k);
    let mut out = Vec::with_capacity(k);
    for off in offsets {
        let mut rem = off;
        for &(b, e) in &filtered {
            let len = e - b;
            if rem < len {
                out.push(b + rem);
                break;
            }
            rem -= len;
        }
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Geometry;
    use crate::tree::interaction_lists;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn complement_excludes_ranges() {
        let mut rng = Rng::new(71);
        let ranges = [(10, 20), (40, 50)];
        let s = sample_complement(100, &ranges, 30, &mut rng);
        assert_eq!(s.len(), 30);
        for &i in &s {
            assert!(i < 100);
            assert!(!(10..20).contains(&i) && !(40..50).contains(&i));
        }
        // distinct
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), s.len());
    }

    #[test]
    fn complement_takes_all_when_small() {
        let mut rng = Rng::new(73);
        let ranges = [(0, 95)];
        let s = sample_complement(100, &ranges, 30, &mut rng);
        assert_eq!(s, vec![95, 96, 97, 98, 99]);
        // k == 0 means "all"
        let s = sample_complement(100, &[(50, 100)], 0, &mut rng);
        assert_eq!(s.len(), 50);
    }

    #[test]
    fn union_excludes_self() {
        let mut rng = Rng::new(75);
        let ranges = [(0, 10), (10, 20), (30, 40)];
        let s = sample_union(&ranges, (10, 20), 100, &mut rng);
        assert_eq!(s.len(), 20);
        for &i in &s {
            assert!(!(10..20).contains(&i));
        }
    }

    #[test]
    fn near_ranges_cover_self() {
        let g = Geometry::sphere_surface(512, 77);
        let t = ClusterTree::build(&g, 64);
        let lists = interaction_lists(&t, 1.0);
        let l = t.depth;
        for i in 0..t.width(l) {
            let nr = near_ranges(&t, &lists[l], l, i);
            let node = t.node(l, i);
            assert!(nr.contains(&(node.begin, node.end)), "self must be near");
        }
    }

    #[test]
    fn prop_complement_union_partition() {
        // complement(ranges) ∪ union(ranges) == [0, n) when both unsampled.
        check(
            &PropConfig { cases: 32, seed: 0xDEED },
            |rng| {
                let n = 50 + rng.below(200);
                // random disjoint sorted ranges
                let mut cuts: Vec<usize> = (0..6).map(|_| rng.below(n)).collect();
                cuts.sort_unstable();
                cuts.dedup();
                let mut ranges = Vec::new();
                for w in cuts.chunks(2) {
                    if w.len() == 2 && w[0] < w[1] {
                        ranges.push((w[0], w[1]));
                    }
                }
                (n, ranges)
            },
            |(n, ranges)| {
                let mut rng = Rng::new(1);
                let comp = sample_complement(*n, ranges, 0, &mut rng);
                let uni = sample_union(ranges, (usize::MAX, usize::MAX), 0, &mut rng);
                let mut all: Vec<usize> = comp.iter().chain(uni.iter()).copied().collect();
                all.sort_unstable();
                if all != (0..*n).collect::<Vec<_>>() {
                    return Err(format!("partition broken: {} items vs {}", all.len(), n));
                }
                Ok(())
            },
        );
    }
}
