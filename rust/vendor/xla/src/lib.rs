//! Compile-time stub of the `xla` PJRT bindings used by
//! `h2ulv::runtime::PjrtBackend` and `examples/pjrt_smoke.rs`.
//!
//! The real crate links against native XLA libraries that are not available
//! in this offline build. This stub keeps every PJRT code path *compiling*
//! while reporting the runtime as unavailable from [`PjRtClient::cpu`], so
//! callers (the CLI, the solver facade, the backend-parity tests) cleanly
//! fall back to the native backend. Swapping this path dependency for the
//! real bindings re-enables the AOT artifact execution path without any
//! source change in `h2ulv`.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str =
    "XLA/PJRT runtime not available: h2ulv was built against the offline xla stub";

/// Error type mirroring the real bindings' error surface.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Parsed HLO module (text format emitted by `python/compile/aot.py`).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Compiled, device-loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Host literal (dense array value).
#[derive(Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1, 1]).is_err());
    }
}
