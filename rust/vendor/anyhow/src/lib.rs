//! Minimal offline stand-in for the `anyhow` crate (this build environment
//! has no crates.io access — DESIGN.md §10). Implements exactly the subset
//! the workspace uses: [`Error`], [`Result`], and the [`anyhow!`] macro.
//!
//! Like the real crate, [`Error`] deliberately does *not* implement
//! `std::error::Error`; that is what makes the blanket
//! `impl From<E: std::error::Error>` coherent.

use std::fmt;

/// A type-erased error carrying a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($tt:tt)*) => {
        $crate::Error::msg(format!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_std_error_and_macro() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        let m = anyhow!("bad {}", 7);
        assert_eq!(m.to_string(), "bad 7");
    }
}
